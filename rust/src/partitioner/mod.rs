//! Model Partitioner — paper §III-B.
//!
//! B1 *Layer Analysis* is done by the AOT manifest (type + attributes per
//! module). B2 *Cost Estimation* is [`cost::layer_cost`] (Eq. 1/2/9).
//! B3 *Partition Boundaries* is the greedy cumulative-cost algorithm
//! (Eq. 3/10): accumulate layers until the running cost reaches
//! `total / num_partitions`, cut, repeat; remaining layers join the last
//! partition. B4 *Distributed Model* maps the layer-granular cuts onto the
//! AOT block grid so every partition is executable (a residual-carrying
//! block cannot be split mid-way — tensors only exist at block edges).
//!
//! Two refinements beyond the paper's greedy scheme, both ablated in
//! `benches/partitioner.rs`:
//!  * capability-weighted targets ([`plan_weighted`]): per-partition target
//!    cost proportional to each node's CPU share, so heterogeneous clusters
//!    get proportionally-sized partitions;
//!  * [`Plan::comm_bytes`] exposes the activation payload at every cut so
//!    the scheduler/deployer can reason about communication overhead.

pub mod cost;

use anyhow::Result;

use crate::manifest::Manifest;

/// A partition: a half-open range over the flat layer list, plus the
/// realized (block-aligned) range actually deployed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Partition {
    /// Layer-granular boundary from the paper's algorithm (reported in
    /// §IV-D as partition *sizes*).
    pub layer_range: std::ops::Range<usize>,
    /// Block-aligned realization (what the deployer ships and runs).
    pub block_range: std::ops::Range<usize>,
    /// Eq. 9 cost of the layer range.
    pub cost: u64,
}

impl Partition {
    pub fn layer_count(&self) -> usize {
        self.layer_range.len()
    }
}

/// A complete partition plan for one model manifest.
#[derive(Debug, Clone)]
pub struct Plan {
    pub partitions: Vec<Partition>,
    pub total_cost: u64,
}

impl Plan {
    /// Paper §IV-D "partition sizes": layer counts per partition.
    pub fn layer_sizes(&self) -> Vec<usize> {
        self.partitions.iter().map(Partition::layer_count).collect()
    }

    pub fn block_ranges(&self) -> Vec<std::ops::Range<usize>> {
        self.partitions.iter().map(|p| p.block_range.clone()).collect()
    }

    /// Activation bytes crossing each inter-partition edge at `batch`.
    pub fn comm_bytes(&self, manifest: &Manifest, batch: usize) -> Vec<u64> {
        self.partitions
            .iter()
            .take(self.partitions.len().saturating_sub(1))
            .map(|p| {
                let last_block = p.block_range.end - 1;
                manifest.blocks[last_block].output_bytes(batch)
            })
            .collect()
    }

    /// Weight payload shipped to the node hosting each partition.
    pub fn weights_bytes(&self, manifest: &Manifest) -> Vec<u64> {
        self.partitions
            .iter()
            .map(|p| manifest.weights_bytes_for(p.block_range.clone()))
            .collect()
    }

    /// Largest-to-smallest cost imbalance ratio (1.0 = perfectly balanced).
    pub fn imbalance(&self) -> f64 {
        let costs: Vec<u64> = self.partitions.iter().map(|p| p.cost).collect();
        let max = *costs.iter().max().unwrap_or(&0) as f64;
        let min = *costs.iter().min().unwrap_or(&0) as f64;
        if min == 0.0 {
            f64::INFINITY
        } else {
            max / min
        }
    }
}

/// Exclusive prefix sums over layer costs: `prefix[i] = Σ costs[..i]`,
/// length `costs.len() + 1`. Computed once per plan so every candidate
/// range's cost is an O(1) [`range_cost`] lookup instead of an O(L)
/// rescan of `costs[range]` (which made boundary realization and
/// rebalance re-plans O(L·P) in aggregate).
pub fn prefix_sums(costs: &[u64]) -> Vec<u64> {
    let mut out = Vec::with_capacity(costs.len() + 1);
    let mut acc = 0u64;
    out.push(0);
    for &c in costs {
        acc += c;
        out.push(acc);
    }
    out
}

/// O(1) cost of a half-open layer range, given [`prefix_sums`] output.
pub fn range_cost(prefix: &[u64], r: &std::ops::Range<usize>) -> u64 {
    prefix[r.end] - prefix[r.start]
}

/// Greedy layer-boundary computation — the paper's Eq. 3/10 algorithm,
/// parameterized by the cost function so the ablation can swap models.
pub fn layer_boundaries_with(
    costs: &[u64],
    num_partitions: usize,
) -> Vec<std::ops::Range<usize>> {
    assert!(num_partitions >= 1, "num_partitions must be >= 1");
    let total: u64 = costs.iter().sum();
    let target = total as f64 / num_partitions as f64;
    let mut ranges = Vec::with_capacity(num_partitions);
    let mut start = 0usize;
    let mut acc = 0u64;
    for (i, &c) in costs.iter().enumerate() {
        acc += c;
        if acc as f64 >= target && ranges.len() < num_partitions - 1 {
            ranges.push(start..i + 1);
            start = i + 1;
            acc = 0;
        }
    }
    ranges.push(start..costs.len());
    // Degenerate inputs (more partitions than layers with cost) can leave
    // empty trailing ranges; keep them — callers validate.
    while ranges.len() < num_partitions {
        ranges.push(costs.len()..costs.len());
    }
    ranges
}

/// Snap a layer index to the nearest block-start boundary (>= snapping up
/// to the block containing the cut, choosing the closer edge by layer
/// distance, never producing empty blocks ranges).
fn snap_to_block(layer_cut: usize, offsets: &[usize]) -> usize {
    // offsets: layer index at which each block starts, plus total at end.
    // Find the block whose range contains layer_cut, then pick the nearer
    // of its two edges.
    match offsets.binary_search(&layer_cut) {
        Ok(i) => i,                 // exactly on a block edge
        Err(i) => {
            // layer_cut falls inside block i-1 (offsets[i-1] < cut < offsets[i]).
            let lo = offsets[i - 1];
            let hi = offsets[i];
            if layer_cut - lo <= hi - layer_cut {
                i - 1
            } else {
                i
            }
        }
    }
}

fn realize(
    manifest: &Manifest,
    layer_ranges: Vec<std::ops::Range<usize>>,
    costs: &[u64],
) -> Result<Plan> {
    let offsets = manifest.block_layer_offsets();
    let n_blocks = manifest.blocks.len();
    let mut block_cuts: Vec<usize> = vec![0];
    for r in layer_ranges.iter().take(layer_ranges.len() - 1) {
        let mut cut = snap_to_block(r.end, &offsets);
        // Enforce strictly increasing cuts so no partition is block-empty.
        let prev = *block_cuts.last().unwrap();
        if cut <= prev {
            cut = (prev + 1).min(n_blocks);
        }
        block_cuts.push(cut);
    }
    block_cuts.push(n_blocks);
    // Backward pass: the forward clamp can leave a cut colliding with the
    // fixed end (e.g. greedy plans that exhaust all cost early). Pull such
    // cuts back so every partition keeps at least one block.
    for i in (1..block_cuts.len() - 1).rev() {
        if block_cuts[i] >= block_cuts[i + 1] {
            block_cuts[i] = block_cuts[i + 1].saturating_sub(1);
        }
    }

    let prefix = prefix_sums(costs);
    let total_cost = *prefix.last().unwrap();
    let partitions = layer_ranges
        .iter()
        .enumerate()
        .map(|(i, lr)| Partition {
            cost: range_cost(&prefix, lr),
            layer_range: lr.clone(),
            block_range: block_cuts[i]..block_cuts[i + 1],
        })
        .collect::<Vec<_>>();
    // Validity: block ranges must tile [0, n_blocks).
    anyhow::ensure!(
        partitions.first().map(|p| p.block_range.start) == Some(0)
            && partitions.last().map(|p| p.block_range.end) == Some(n_blocks),
        "partition block ranges must tile the model"
    );
    for pair in partitions.windows(2) {
        anyhow::ensure!(
            pair[0].block_range.end == pair[1].block_range.start,
            "block ranges must be contiguous"
        );
    }
    anyhow::ensure!(
        partitions.iter().all(|p| !p.block_range.is_empty()),
        "every partition needs at least one block (requested {} partitions \
         for {} blocks)",
        partitions.len(),
        n_blocks
    );
    Ok(Plan { partitions, total_cost })
}

/// Paper algorithm: equal cost targets (Eq. 3).
pub fn plan(manifest: &Manifest, num_partitions: usize) -> Result<Plan> {
    anyhow::ensure!(num_partitions >= 1, "num_partitions must be >= 1");
    anyhow::ensure!(
        num_partitions <= manifest.blocks.len(),
        "cannot make {num_partitions} partitions from {} blocks",
        manifest.blocks.len()
    );
    let costs: Vec<u64> =
        manifest.flat_layers().iter().map(|l| cost::layer_cost(l)).collect();
    let ranges = layer_boundaries_with(&costs, num_partitions);
    realize(manifest, ranges, &costs)
}

/// Capability-weighted variant: target cost per partition proportional to
/// `weights[i]` (e.g. node CPU shares), so a 1.0/0.6/0.4-CPU cluster gets
/// partitions sized 50%/30%/20% of total cost.
pub fn plan_weighted(manifest: &Manifest, weights: &[f64]) -> Result<Plan> {
    anyhow::ensure!(!weights.is_empty(), "weights must be non-empty");
    anyhow::ensure!(
        weights.iter().all(|w| *w > 0.0),
        "weights must be positive"
    );
    anyhow::ensure!(
        weights.len() <= manifest.blocks.len(),
        "cannot make {} partitions from {} blocks",
        weights.len(),
        manifest.blocks.len()
    );
    let costs: Vec<u64> =
        manifest.flat_layers().iter().map(|l| cost::layer_cost(l)).collect();
    let total: u64 = costs.iter().sum();
    let wsum: f64 = weights.iter().sum();

    let mut ranges = Vec::with_capacity(weights.len());
    let mut start = 0usize;
    let mut acc = 0u64;
    let mut w_iter = weights.iter();
    let mut target = total as f64 * w_iter.next().unwrap() / wsum;
    for (i, &c) in costs.iter().enumerate() {
        acc += c;
        if acc as f64 >= target && ranges.len() < weights.len() - 1 {
            ranges.push(start..i + 1);
            start = i + 1;
            acc = 0;
            target = total as f64 * w_iter.next().unwrap() / wsum;
        }
    }
    ranges.push(start..costs.len());
    while ranges.len() < weights.len() {
        ranges.push(costs.len()..costs.len());
    }
    realize(manifest, ranges, &costs)
}

/// Profile-guided partitioning (extension; paper §V "automate partition
/// optimization"): balance partitions on *measured* per-block execution
/// times instead of the Eq. 9 static cost model, which misjudges where
/// wall time actually goes (e.g. it prices the classifier at ~3% of the
/// model while it measures at ~45% at batch 1). Boundaries are chosen at
/// block granularity directly.
pub fn plan_measured(
    manifest: &Manifest,
    block_ms: &[f64],
    num_partitions: usize,
) -> Result<Plan> {
    plan_measured_weighted(manifest, block_ms, &vec![1.0; num_partitions])
}

/// Profile-guided *and* capability-weighted: per-partition time targets
/// proportional to each node's CPU share, over measured block costs. This
/// is what makes heterogeneous pipelines run stage-balanced in *wall
/// time* (each stage's `measured_ms / cpu_share` equalizes).
pub fn plan_measured_weighted(
    manifest: &Manifest,
    block_ms: &[f64],
    weights: &[f64],
) -> Result<Plan> {
    let num_partitions = weights.len();
    anyhow::ensure!(
        block_ms.len() == manifest.blocks.len(),
        "need one measured cost per block ({} != {})",
        block_ms.len(),
        manifest.blocks.len()
    );
    anyhow::ensure!(num_partitions >= 1, "need >= 1 weight");
    anyhow::ensure!(
        weights.iter().all(|w| *w > 0.0),
        "weights must be positive"
    );
    anyhow::ensure!(
        num_partitions <= manifest.blocks.len(),
        "cannot make {num_partitions} partitions from {} blocks",
        manifest.blocks.len()
    );
    let total: f64 = block_ms.iter().sum();
    let wsum: f64 = weights.iter().sum();
    let n_blocks = manifest.blocks.len();
    let mut cuts = vec![0usize];
    let mut w_iter = weights.iter();
    let mut target = total * w_iter.next().unwrap() / wsum;
    let mut acc = 0.0;
    for (i, &c) in block_ms.iter().enumerate() {
        if cuts.len() == num_partitions {
            break;
        }
        let parts_needed = num_partitions - cuts.len();
        // Cut *before* this block when that lands closer to the target
        // than cutting after it (minimizes per-partition deviation).
        let over = acc + c - target;
        let under = target - acc;
        if acc > 0.0 && over > under && n_blocks - i >= parts_needed {
            cuts.push(i);
            acc = c;
            target = total * w_iter.next().unwrap() / wsum;
        } else {
            acc += c;
            if acc >= target && n_blocks - (i + 1) >= parts_needed {
                cuts.push(i + 1);
                acc = 0.0;
                target = total * w_iter.next().unwrap() / wsum;
            }
        }
    }
    while cuts.len() < num_partitions {
        // Degenerate: force single-block partitions at the tail.
        let prev = *cuts.last().unwrap();
        cuts.push((prev + 1).min(manifest.blocks.len() - (num_partitions - cuts.len())));
    }
    cuts.push(manifest.blocks.len());
    for i in (1..cuts.len() - 1).rev() {
        if cuts[i] >= cuts[i + 1] {
            cuts[i] = cuts[i + 1].saturating_sub(1);
        }
    }

    let offsets = manifest.block_layer_offsets();
    let costs: Vec<u64> =
        manifest.flat_layers().iter().map(|l| cost::layer_cost(l)).collect();
    let prefix = prefix_sums(&costs);
    let total_cost = *prefix.last().unwrap();
    let partitions = (0..num_partitions)
        .map(|i| {
            let br = cuts[i]..cuts[i + 1];
            let lr = offsets[br.start]..offsets[br.end];
            Partition {
                cost: range_cost(&prefix, &lr),
                layer_range: lr,
                block_range: br,
            }
        })
        .collect::<Vec<_>>();
    anyhow::ensure!(
        partitions.iter().all(|p| !p.block_range.is_empty()),
        "measured plan produced an empty partition"
    );
    Ok(Plan { partitions, total_cost })
}

/// Scale-out: distribute `extra` additional replicas over stages,
/// bottleneck-first. Every stage starts with one replica; each extra goes
/// to the stage whose *effective* cost (`cost / replicas`) is currently
/// largest, so a skewed profile concentrates replicas on its bottleneck
/// while a balanced one spreads them round-robin. Costs are whatever the
/// caller balances on (Eq. 9 partition costs from [`prefix_sums`] ranges,
/// or measured stage milliseconds); zero budget returns all-ones — the
/// k=1 degenerate plan.
pub fn replica_counts(stage_costs: &[f64], extra: usize) -> Vec<usize> {
    let mut reps = vec![1usize; stage_costs.len()];
    if stage_costs.is_empty() {
        return reps;
    }
    for _ in 0..extra {
        let bottleneck = (0..reps.len())
            .max_by(|&a, &b| {
                let ea = stage_costs[a] / reps[a] as f64;
                let eb = stage_costs[b] / reps[b] as f64;
                // total_cmp: a NaN cost must not wedge the argmax.
                ea.total_cmp(&eb)
            })
            .expect("non-empty stage list");
        reps[bottleneck] += 1;
    }
    reps
}

/// Ablation: the paper's greedy algorithm under the corrected (group-aware)
/// cost model. Returns layer sizes only (no realization needed for study).
pub fn layer_sizes_flops_cost(manifest: &Manifest, num_partitions: usize) -> Vec<usize> {
    let costs: Vec<u64> =
        manifest.flat_layers().iter().map(|l| cost::flops_cost(l)).collect();
    layer_boundaries_with(&costs, num_partitions)
        .into_iter()
        .map(|r| r.len())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::testutil::tiny_manifest;
    use crate::util::check::forall;
    use crate::util::rng::Rng;

    #[test]
    fn single_partition_is_whole_model() {
        let m = tiny_manifest();
        let p = plan(&m, 1).unwrap();
        assert_eq!(p.layer_sizes(), vec![4]);
        assert_eq!(p.block_ranges(), vec![0..3]);
    }

    #[test]
    fn partitions_tile_layers_and_blocks() {
        let m = tiny_manifest();
        for n in 1..=3 {
            let p = plan(&m, n).unwrap();
            assert_eq!(p.layer_sizes().iter().sum::<usize>(), 4);
            assert_eq!(p.partitions.len(), n);
            assert_eq!(p.partitions[0].block_range.start, 0);
            assert_eq!(p.partitions.last().unwrap().block_range.end, 3);
        }
    }

    #[test]
    fn too_many_partitions_rejected() {
        let m = tiny_manifest();
        assert!(plan(&m, 4).is_err());
        assert!(plan(&m, 0).is_err());
    }

    #[test]
    fn greedy_matches_hand_computation() {
        // costs: a.conv 3*3*4*8=288, a.bn 0 (params=0? bn params = c*c ->
        // in tiny manifest bn has params 0 since c_in=c_out=0) -> layer
        // costs [288, 0, 576, 80].
        let costs = vec![288u64, 0, 576, 80];
        let r = layer_boundaries_with(&costs, 2);
        // total=944, target=472; cumulative 288,288,864 -> cut after idx 2.
        assert_eq!(r, vec![0..3, 3..4]);
    }

    #[test]
    fn weighted_plan_respects_weights_direction() {
        let m = tiny_manifest();
        let p_eq = plan(&m, 2).unwrap();
        let p_heavy_first = plan_weighted(&m, &[10.0, 1.0]).unwrap();
        // Giving partition 0 more weight can only move its boundary later
        // (or keep it).
        assert!(
            p_heavy_first.partitions[0].layer_range.end
                >= p_eq.partitions[0].layer_range.end
        );
    }

    #[test]
    fn snap_prefers_nearest_edge() {
        let offsets = vec![0, 3, 8, 10];
        assert_eq!(snap_to_block(3, &offsets), 1); // exact edge
        assert_eq!(snap_to_block(4, &offsets), 1); // closer to 3
        assert_eq!(snap_to_block(7, &offsets), 2); // closer to 8
        assert_eq!(snap_to_block(0, &offsets), 0);
        assert_eq!(snap_to_block(10, &offsets), 3);
    }

    #[test]
    fn property_boundaries_cover_exactly_once() {
        forall(200, 0xA11CE, |rng: &mut Rng| {
            let n_layers = rng.range(1, 40);
            let costs: Vec<u64> =
                (0..n_layers).map(|_| rng.below(1000) as u64).collect();
            let parts = rng.range(1, n_layers.min(8));
            let ranges = layer_boundaries_with(&costs, parts);
            assert_eq!(ranges.len(), parts);
            // Tiling: consecutive, total coverage.
            assert_eq!(ranges[0].start, 0);
            assert_eq!(ranges.last().unwrap().end, n_layers);
            for pair in ranges.windows(2) {
                assert_eq!(pair[0].end, pair[1].start);
            }
        });
    }

    #[test]
    fn property_cost_balance_bound() {
        // Every non-final partition's cost exceeds target only by at most
        // the largest single layer cost (greedy overshoot bound).
        forall(200, 0xB0B, |rng: &mut Rng| {
            let n_layers = rng.range(2, 60);
            let costs: Vec<u64> =
                (0..n_layers).map(|_| 1 + rng.below(1000) as u64).collect();
            let parts = rng.range(2, n_layers.min(6));
            let total: u64 = costs.iter().sum();
            let target = total as f64 / parts as f64;
            let max_layer = *costs.iter().max().unwrap() as f64;
            let ranges = layer_boundaries_with(&costs, parts);
            let prefix = prefix_sums(&costs);
            for r in ranges.iter().take(parts - 1) {
                let c = range_cost(&prefix, r);
                assert!(
                    (c as f64) < target + max_layer,
                    "partition cost {c} exceeds target {target} + max {max_layer}"
                );
            }
        });
    }

    #[test]
    fn prefix_sums_match_naive_range_sums() {
        // Equivalence pin for the O(1) range-cost path: every random
        // range's prefix-difference equals the naive rescan.
        forall(200, 0x9F5, |rng: &mut Rng| {
            let n = rng.range(1, 60);
            let costs: Vec<u64> =
                (0..n).map(|_| rng.below(1000) as u64).collect();
            let prefix = prefix_sums(&costs);
            assert_eq!(prefix.len(), n + 1);
            assert_eq!(*prefix.last().unwrap(), costs.iter().sum::<u64>());
            for _ in 0..10 {
                let a = rng.below(n + 1);
                let b = rng.below(n + 1);
                let (a, b) = if a <= b { (a, b) } else { (b, a) };
                let naive: u64 = costs[a..b].iter().sum();
                assert_eq!(range_cost(&prefix, &(a..b)), naive);
            }
        });
    }

    #[test]
    fn plan_costs_agree_with_naive_rescan() {
        // The realized plans must report exactly the costs a naive
        // per-range rescan would (the prefix-sum refactor is pure perf).
        let m = tiny_manifest();
        let costs: Vec<u64> = m
            .flat_layers()
            .iter()
            .map(|l| cost::layer_cost(l))
            .collect();
        for n in 1..=3 {
            let p = plan(&m, n).unwrap();
            for part in &p.partitions {
                let naive: u64 =
                    costs[part.layer_range.clone()].iter().sum();
                assert_eq!(part.cost, naive);
            }
            assert_eq!(p.total_cost, costs.iter().sum::<u64>());
        }
    }

    #[test]
    fn property_weighted_plan_valid_on_tiny() {
        let m = tiny_manifest();
        forall(100, 0xCAFE, |rng: &mut Rng| {
            let n = rng.range(1, 3);
            let weights: Vec<f64> =
                (0..n).map(|_| 0.1 + rng.f64()).collect();
            let p = plan_weighted(&m, &weights).unwrap();
            assert_eq!(p.partitions.len(), n);
            assert_eq!(p.layer_sizes().iter().sum::<usize>(), 4);
            assert!(p.partitions.iter().all(|x| !x.block_range.is_empty()));
        });
    }

    #[test]
    fn measured_plan_balances_on_real_costs() {
        let m = tiny_manifest();
        // Block 2 is by far the most expensive: a 2-way plan must isolate it.
        let p = plan_measured(&m, &[1.0, 1.0, 10.0], 2).unwrap();
        assert_eq!(p.block_ranges(), vec![0..2, 2..3]);
        // Uniform costs split evenly.
        let p = plan_measured(&m, &[1.0, 1.0, 1.0], 3).unwrap();
        assert_eq!(p.block_ranges(), vec![0..1, 1..2, 2..3]);
        assert!(plan_measured(&m, &[1.0], 2).is_err());
    }

    #[test]
    fn property_measured_plan_tiles_blocks() {
        let m = tiny_manifest();
        forall(100, 0x11EA5, |rng: &mut Rng| {
            let costs: Vec<f64> = (0..3).map(|_| 0.1 + rng.f64() * 10.0).collect();
            let n = rng.range(1, 3);
            let p = plan_measured(&m, &costs, n).unwrap();
            assert_eq!(p.partitions.len(), n);
            assert_eq!(p.partitions[0].block_range.start, 0);
            assert_eq!(p.partitions.last().unwrap().block_range.end, 3);
            for pair in p.partitions.windows(2) {
                assert_eq!(pair[0].block_range.end, pair[1].block_range.start);
            }
            assert_eq!(p.layer_sizes().iter().sum::<usize>(), 4);
        });
    }

    #[test]
    fn replica_counts_are_bottleneck_first() {
        // Skewed profile: the 4x stage absorbs every extra until its
        // effective cost drops to parity.
        assert_eq!(replica_counts(&[1.0, 1.0, 4.0, 1.0], 0), vec![1, 1, 1, 1]);
        assert_eq!(replica_counts(&[1.0, 1.0, 4.0, 1.0], 1), vec![1, 1, 2, 1]);
        assert_eq!(replica_counts(&[1.0, 1.0, 4.0, 1.0], 3), vec![1, 1, 4, 1]);
        // Balanced profile: extras spread instead of stacking.
        let r = replica_counts(&[1.0, 1.0, 1.0], 3);
        assert_eq!(r, vec![2, 2, 2]);
        assert_eq!(replica_counts(&[], 5), Vec::<usize>::new());
    }

    #[test]
    fn property_replica_counts_conserve_budget_and_shrink_bottleneck() {
        forall(200, 0x5CA1E, |rng: &mut Rng| {
            let n = rng.range(1, 8);
            let costs: Vec<f64> =
                (0..n).map(|_| 0.5 + rng.f64() * 10.0).collect();
            let extra = rng.below(12);
            let reps = replica_counts(&costs, extra);
            assert_eq!(reps.len(), n);
            assert!(reps.iter().all(|&r| r >= 1));
            assert_eq!(reps.iter().sum::<usize>(), n + extra);
            if extra > 0 {
                // The max effective cost never increases vs the k=1 plan.
                let eff = |rs: &[usize]| {
                    costs
                        .iter()
                        .zip(rs)
                        .map(|(c, &r)| c / r as f64)
                        .fold(f64::MIN, f64::max)
                };
                assert!(eff(&reps) <= eff(&vec![1; n]) + 1e-12);
            }
        });
    }

    #[test]
    fn comm_and_weight_bytes() {
        let m = tiny_manifest();
        let p = plan(&m, 2).unwrap();
        let comm = p.comm_bytes(&m, 1);
        assert_eq!(comm.len(), 1);
        assert!(comm[0] > 0);
        let wb = p.weights_bytes(&m);
        assert_eq!(wb.iter().sum::<u64>(), 1200);
    }
}
