//! Layer cost model — the paper's Eq. 1, 2 and 9, verbatim.
//!
//! ```text
//! LayerCost(l) = k_h * k_w * c_in * c_out    for Conv2D      (Eq. 1)
//!              = n_in * n_out                for Linear      (Eq. 2)
//!              = params_count                otherwise       (Eq. 9)
//! ```
//!
//! Note the paper reads `Conv2d.in_channels` / `out_channels` module
//! attributes verbatim, so depthwise convs (groups == channels) cost
//! `9 * C * C` even though they perform `9 * C` MACs per pixel — a quirk we
//! preserve deliberately: reproducing the paper's reported partition sizes
//! [116, 25] and [108, 16, 17] requires the same cost function they used.
//! `flops_cost` below is the corrected alternative used by the ablation
//! bench (`benches/partitioner.rs`).

use crate::manifest::{LayerKind, LayerMeta};

/// Paper Eq. 9 cost of a single layer.
pub fn layer_cost(l: &LayerMeta) -> u64 {
    match l.kind {
        LayerKind::Conv2d => {
            l.k_h as u64 * l.k_w as u64 * l.c_in as u64 * l.c_out as u64
        }
        LayerKind::Linear => l.n_in as u64 * l.n_out as u64,
        _ => l.params,
    }
}

/// Group-aware (true-MAC-proportional) cost: divides conv cost by `groups`.
/// Not what the paper used; exercised by the ablation study to show how the
/// boundary placement shifts under a corrected cost model.
pub fn flops_cost(l: &LayerMeta) -> u64 {
    match l.kind {
        LayerKind::Conv2d => {
            l.k_h as u64 * l.k_w as u64 * l.c_in as u64 * l.c_out as u64
                / l.groups.max(1) as u64
        }
        LayerKind::Linear => l.n_in as u64 * l.n_out as u64,
        _ => l.params,
    }
}

/// Total cost of a slice of layers under the paper cost model.
pub fn total_cost<'a, I: IntoIterator<Item = &'a LayerMeta>>(layers: I) -> u64 {
    layers.into_iter().map(layer_cost).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::LayerKind;

    fn conv(k: u32, cin: u32, cout: u32, groups: u32) -> LayerMeta {
        LayerMeta {
            name: "c".into(),
            kind: LayerKind::Conv2d,
            params: (k * k * cin / groups * cout) as u64,
            k_h: k,
            k_w: k,
            c_in: cin,
            c_out: cout,
            groups,
            stride: 1,
            n_in: 0,
            n_out: 0,
        }
    }

    #[test]
    fn conv_cost_eq1() {
        assert_eq!(layer_cost(&conv(3, 3, 32, 1)), 3 * 3 * 3 * 32);
    }

    #[test]
    fn depthwise_uses_module_attrs_not_groups() {
        // Paper quirk: depthwise counts as kh*kw*C*C.
        let dw = conv(3, 32, 32, 32);
        assert_eq!(layer_cost(&dw), 9 * 32 * 32);
        assert_eq!(flops_cost(&dw), 9 * 32);
    }

    #[test]
    fn linear_cost_eq2() {
        let l = LayerMeta {
            name: "fc".into(),
            kind: LayerKind::Linear,
            params: 1280 * 1000 + 1000,
            k_h: 0,
            k_w: 0,
            c_in: 0,
            c_out: 0,
            groups: 1,
            stride: 1,
            n_in: 1280,
            n_out: 1000,
        };
        assert_eq!(layer_cost(&l), 1280 * 1000);
    }

    #[test]
    fn other_layers_use_params() {
        let bn = LayerMeta {
            name: "bn".into(),
            kind: LayerKind::BatchNorm2d,
            params: 64,
            k_h: 0,
            k_w: 0,
            c_in: 0,
            c_out: 0,
            groups: 1,
            stride: 1,
            n_in: 0,
            n_out: 0,
        };
        assert_eq!(layer_cost(&bn), 64);
    }
}
