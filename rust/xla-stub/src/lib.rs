//! Stub of the `xla` crate's PJRT surface used by `amp4ec::runtime`.
//!
//! Mirrors the exact signatures the runtime layer calls
//! (`PjRtClient::cpu`, `compile`, `buffer_from_host_buffer`,
//! `HloModuleProto::from_text_file`, `execute`/`execute_b`, literal
//! conversions) but every operation that would need a real PJRT client
//! returns [`Error`] with a clear message. Artifact-gated integration
//! tests skip before reaching these paths; everything else — unit
//! tests, the virtual-cluster substrate, the streaming-engine benches
//! and examples — is pure Rust and runs fine.
//!
//! To execute real compiled artifacts, point the workspace `xla`
//! dependency at the actual `xla` crate (xla-rs over xla_extension)
//! instead of this stub; `amp4ec` needs no source changes.

use std::path::Path;

/// Stub error: carries the operation name so failures read as
/// "PJRT unavailable", not as a model bug.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

type Result<T> = std::result::Result<T, Error>;

fn unavailable(op: &str) -> Error {
    Error(format!(
        "xla stub: {op} requires the real PJRT runtime (build with the \
         actual `xla` crate to execute compiled artifacts)"
    ))
}

/// Stub PJRT CPU client. Construction succeeds so the process can boot
/// and report a platform; compilation/execution fail with [`Error`].
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "stub-cpu".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("compile"))
    }

    pub fn buffer_from_host_buffer<T>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(unavailable("buffer_from_host_buffer"))
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        Err(Error(format!(
            "xla stub: cannot parse HLO artifact {} (real PJRT runtime \
             required)",
            path.as_ref().display()
        )))
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("execute"))
    }

    pub fn execute_b<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("execute_b"))
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("to_literal_sync"))
    }
}

pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        Err(unavailable("to_tuple1"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("to_vec"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_boots_but_compile_fails_loudly() {
        let c = PjRtClient::cpu().unwrap();
        assert_eq!(c.platform_name(), "stub-cpu");
        let comp = XlaComputation::from_proto(&HloModuleProto);
        let err = c.compile(&comp).unwrap_err();
        assert!(format!("{err}").contains("xla stub"));
    }
}
