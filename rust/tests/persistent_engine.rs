//! Persistent cross-batch streaming engine: multi-batch bit-identity
//! against the serial schedule, interleaved submissions, heterogeneous
//! stage chains, mid-stream failure isolation, and adaptive depth — all
//! on the deterministic harness (`common::harness`, no compiled
//! artifacts needed) — plus an artifact-gated end-to-end adaptive
//! serve.

mod common;

use common::harness as h;

use amp4ec::config::AmpConfig;
use amp4ec::pipeline::engine::{
    run_serial, run_streamed, EngineConfig, PersistentEngine,
};
use amp4ec::runtime::Tensor;
use amp4ec::server::EdgeServer;
use amp4ec::workload::Arrival;

#[test]
fn interleaved_batches_stay_bit_identical_to_serial() {
    let stages = h::paper_stages(2.0);
    let engine = h::engine(stages.clone(), 4);
    // Distinct inputs, all submitted before any wait: micro-batches of
    // different batches interleave in the stage queues.
    let batches: Vec<Tensor> =
        (0..6).map(|i| h::seeded_input(3, 5, 100 + i)).collect();
    let handles: Vec<_> =
        batches.iter().map(|b| engine.submit(b).unwrap()).collect();
    for (b, hdl) in batches.iter().zip(handles) {
        let run = hdl.wait().unwrap();
        let serial = run_serial(&*stages, b, 1).unwrap();
        assert_eq!(run.output, serial.output, "interleaved batch diverged");
        // Batch-local counters: every stage saw exactly this batch's
        // micro-batches.
        assert_eq!(run.stage_counters.len(), 3);
        for c in &run.stage_counters {
            assert_eq!(c.micro_batches, 3);
        }
        // Batch-local timing is self-consistent.
        assert!(run.timing.total_ms > 0.0);
        assert!(run.timing.compute_ms > 0.0);
        assert!(run.timing.activation_bytes > 0);
    }
}

#[test]
fn cross_batch_streaming_eliminates_drain_bubbles() {
    // The PR-2 tentpole claim at engine level: back-to-back batches
    // through the persistent engine beat the same batches run one
    // `run_streamed` call each (which drains the pipeline between
    // batches).
    let stages = h::paper_stages(2.0);
    let n_batches = 8;
    let batches: Vec<Tensor> =
        (0..n_batches).map(|i| h::seeded_input(4, 8, 200 + i)).collect();

    let engine = h::engine(stages.clone(), 4);
    let handles: Vec<_> =
        batches.iter().map(|b| engine.submit(b).unwrap()).collect();
    for hdl in handles {
        hdl.wait().unwrap();
    }
    let cross_ms = engine.makespan_ms();

    let per_batch_stages = h::paper_stages(2.0);
    let cfg = EngineConfig { micro_batch_rows: 1, max_in_flight: 4 };
    let mut per_batch_ms = 0.0;
    for b in &batches {
        per_batch_ms += run_streamed(&*per_batch_stages, b, &cfg)
            .unwrap()
            .timing
            .total_ms;
    }

    // The sim model makes this deterministic; the fill/drain analysis
    // predicts ~34% here, so 15% is a safe floor (the bench pins the
    // >= 20% acceptance number).
    assert!(
        cross_ms * 1.15 < per_batch_ms,
        "cross-batch {cross_ms:.1} ms must be >= 15% under per-batch \
         {per_batch_ms:.1} ms"
    );
    // Cumulative engine counters saw every micro-batch of every batch.
    let totals = engine.total_counters();
    for c in &totals {
        assert_eq!(c.micro_batches, (n_batches * 4) as u64);
    }
}

#[test]
fn mid_stream_failure_leaves_later_batches_unaffected() {
    // Stage 1 rejects activations carrying a sentinel; surrounding
    // batches must complete with consistent counters and the engine must
    // keep serving. (Stage 0's row-wise transform is applied before the
    // activation reaches stage 1, so the stage-1 sentinel is the
    // transformed value.)
    let sent = -1234.5f32;
    let sent_at_1 = sent * 1.5 + 0.25;
    let stages = std::sync::Arc::new(
        h::FaultStages::new(
            amp4ec::pipeline::engine::SimStages::heterogeneous(
                &[1.0, 1.0, 1.0],
                2.0,
            ),
        )
        .fail_on(1, sent_at_1),
    );
    let engine = h::engine(stages.clone(), 3);
    let good_a = h::seeded_input(3, 2, 31);
    let bad = h::sentinel_input(3, 2, sent);
    let good_b = h::seeded_input(3, 2, 32);

    let ha = engine.submit(&good_a).unwrap();
    let hbad = engine.submit(&bad).unwrap();
    let hb = engine.submit(&good_b).unwrap();

    let want_a = run_serial(&*stages, &good_a, 1).unwrap().output;
    let want_b = run_serial(&*stages, &good_b, 1).unwrap().output;
    assert_eq!(ha.wait().unwrap().output, want_a);
    let err = hbad.wait().unwrap_err();
    assert!(
        format!("{err:#}").contains("stage 1"),
        "failure must carry stage context, got: {err:#}"
    );
    let run_b = hb.wait().unwrap();
    assert_eq!(run_b.output, want_b);
    for c in &run_b.stage_counters {
        assert_eq!(
            c.micro_batches, 3,
            "stage {} lost micro-batches after the failure",
            c.stage
        );
    }
    // Still serving after the failure drained.
    assert_eq!(engine.run(&good_a).unwrap().output, want_a);
}

#[test]
fn adaptive_depth_converges_near_best_fixed_depth() {
    // Sweep fixed depths to find the knee (smallest depth within 2% of
    // the best cross-batch throughput), then check the controller parks
    // within one step of it.
    let n_batches = 10;
    let batches: Vec<Tensor> =
        (0..n_batches).map(|i| h::seeded_input(4, 4, 300 + i)).collect();

    let mut best_ms = f64::INFINITY;
    let mut sweep: Vec<(usize, f64)> = Vec::new();
    for depth in 1..=6 {
        let engine = h::engine(h::paper_stages(2.0), depth);
        let handles: Vec<_> =
            batches.iter().map(|b| engine.submit(b).unwrap()).collect();
        for hdl in handles {
            hdl.wait().unwrap();
        }
        let ms = engine.makespan_ms();
        best_ms = best_ms.min(ms);
        sweep.push((depth, ms));
    }
    let best_depth = sweep
        .iter()
        .find(|(_, ms)| *ms <= best_ms * 1.02)
        .map(|(d, _)| *d)
        .unwrap();

    let engine =
        PersistentEngine::new(h::paper_stages(2.0), h::adaptive_cfg(1, 6))
            .unwrap();
    // Longer run so the controller has batches to observe.
    let mut handles = Vec::new();
    for _round in 0..3 {
        for b in &batches {
            handles.push(engine.submit(b).unwrap());
        }
    }
    for hdl in handles {
        hdl.wait().unwrap();
    }
    let final_depth = engine.current_depth() as i64;
    assert!(
        (final_depth - best_depth as i64).abs() <= 1,
        "adaptive depth {final_depth} not within 1 of best fixed depth \
         {best_depth} (sweep: {sweep:?})"
    );
    let report = engine.depth_report();
    assert!(report.widenings >= 1, "controller never widened: {report:?}");
}

#[test]
fn streamed_serving_uses_persistent_engine_end_to_end() {
    require_artifacts!();
    let mut cfg = AmpConfig::paper_cluster_adaptive(&common::artifacts_dir(), 6);
    cfg.pipeline_depth = 2;
    cfg.monitor_interval_ms = 20;
    let server = EdgeServer::start(cfg).unwrap();
    let report = server.serve_workload(16, 16, Arrival::Closed, 7).unwrap();
    assert_eq!(report.metrics.completed, 16);
    assert_eq!(report.metrics.failed, 0);
    // The adaptive engine reported its trajectory and a live window.
    assert!(report.final_pipeline_depth >= 1);
    let depth = report.depth_report.expect("adaptive depth report");
    assert_eq!(depth.initial_depth, 2);
    assert!(depth.final_depth >= 1 && depth.final_depth <= 6);
    // Per-stage budgets are live (uniform mode keeps them in lockstep
    // with the depth) and surfaced in the report.
    assert_eq!(report.stage_budgets.len(), 3);
    assert!(report
        .stage_budgets
        .iter()
        .all(|&b| b == report.final_pipeline_depth));
    // Stage counters flowed through the persistent engine into the
    // report, and the scheduler drained every stage node.
    assert_eq!(report.stage_counters.len(), 3);
    for c in &report.stage_counters {
        assert!(c.micro_batches > 0);
    }
    let sched = server.scheduler.report();
    assert!(sched.active_tasks.iter().all(|(_, active)| *active == 0));
}
