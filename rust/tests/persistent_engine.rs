//! Persistent cross-batch streaming engine: multi-batch bit-identity
//! against the serial schedule, interleaved submissions, heterogeneous
//! stage chains, mid-stream failure isolation, and adaptive depth — all
//! on the virtual-node substrate (no compiled artifacts needed) — plus
//! an artifact-gated end-to-end adaptive serve.

mod common;

use std::sync::Arc;

use amp4ec::config::AmpConfig;
use amp4ec::pipeline::engine::{
    run_serial, run_streamed, AdaptiveDepthConfig, EngineConfig,
    PersistentEngine, PersistentEngineConfig, SimStages,
};
use amp4ec::runtime::Tensor;
use amp4ec::server::EdgeServer;
use amp4ec::workload::Arrival;

fn input(rows: usize, cols: usize, off: f32) -> Tensor {
    let data = (0..rows * cols)
        .map(|i| i as f32 * 0.25 - 2.0 + off)
        .collect();
    Tensor::new(vec![rows, cols], data).unwrap()
}

fn paper_stages() -> Arc<SimStages> {
    Arc::new(SimStages::heterogeneous(&[1.0, 0.6, 0.4], 2.0))
}

#[test]
fn interleaved_batches_stay_bit_identical_to_serial() {
    let stages = paper_stages();
    let engine = PersistentEngine::new(
        Arc::clone(&stages),
        PersistentEngineConfig {
            micro_batch_rows: 1,
            initial_depth: 4,
            adaptive: None,
        },
    )
    .unwrap();
    // Distinct inputs, all submitted before any wait: micro-batches of
    // different batches interleave in the stage queues.
    let batches: Vec<Tensor> =
        (0..6).map(|i| input(3, 5, i as f32 * 7.0)).collect();
    let handles: Vec<_> =
        batches.iter().map(|b| engine.submit(b).unwrap()).collect();
    for (b, h) in batches.iter().zip(handles) {
        let run = h.wait().unwrap();
        let serial = run_serial(&*stages, b, 1).unwrap();
        assert_eq!(run.output, serial.output, "interleaved batch diverged");
        // Batch-local counters: every stage saw exactly this batch's
        // micro-batches.
        assert_eq!(run.stage_counters.len(), 3);
        for c in &run.stage_counters {
            assert_eq!(c.micro_batches, 3);
        }
        // Batch-local timing is self-consistent.
        assert!(run.timing.total_ms > 0.0);
        assert!(run.timing.compute_ms > 0.0);
        assert!(run.timing.activation_bytes > 0);
    }
}

#[test]
fn cross_batch_streaming_eliminates_drain_bubbles() {
    // The tentpole claim at engine level: back-to-back batches through
    // the persistent engine beat the same batches run one `run_streamed`
    // call each (which drains the pipeline between batches).
    let stages = paper_stages();
    let n_batches = 8;
    let batches: Vec<Tensor> =
        (0..n_batches).map(|i| input(4, 8, i as f32)).collect();

    let engine = PersistentEngine::new(
        Arc::clone(&stages),
        PersistentEngineConfig {
            micro_batch_rows: 1,
            initial_depth: 4,
            adaptive: None,
        },
    )
    .unwrap();
    let handles: Vec<_> =
        batches.iter().map(|b| engine.submit(b).unwrap()).collect();
    for h in handles {
        h.wait().unwrap();
    }
    let cross_ms = engine.makespan_ms();

    let per_batch_stages = paper_stages();
    let cfg = EngineConfig { micro_batch_rows: 1, max_in_flight: 4 };
    let mut per_batch_ms = 0.0;
    for b in &batches {
        per_batch_ms += run_streamed(&*per_batch_stages, b, &cfg)
            .unwrap()
            .timing
            .total_ms;
    }

    // The sim model makes this deterministic; the fill/drain analysis
    // predicts ~34% here, so 15% is a safe floor (the bench pins the
    // >= 20% acceptance number).
    assert!(
        cross_ms * 1.15 < per_batch_ms,
        "cross-batch {cross_ms:.1} ms must be >= 15% under per-batch \
         {per_batch_ms:.1} ms"
    );
    // Cumulative engine counters saw every micro-batch of every batch.
    let totals = engine.total_counters();
    for c in &totals {
        assert_eq!(c.micro_batches, (n_batches * 4) as u64);
    }
}

#[test]
fn mid_stream_failure_leaves_later_batches_unaffected() {
    // Stage 1 rejects activations carrying a sentinel; surrounding
    // batches must complete with consistent counters and the engine must
    // keep serving.
    struct FailOnSentinel;
    impl amp4ec::pipeline::engine::StageExec for FailOnSentinel {
        fn num_stages(&self) -> usize {
            3
        }
        fn node_id(&self, stage: usize) -> usize {
            stage
        }
        fn comm_in(&self, _stage: usize, _bytes: u64) -> f64 {
            0.5
        }
        fn comm_out(&self, _bytes: u64) -> f64 {
            0.5
        }
        fn execute(
            &self,
            stage: usize,
            input: Tensor,
        ) -> anyhow::Result<(Tensor, f64)> {
            anyhow::ensure!(
                !(stage == 1 && input.data[0] == -1234.5),
                "sentinel rejected"
            );
            Ok((input, 2.0))
        }
    }

    let engine = PersistentEngine::new(
        Arc::new(FailOnSentinel),
        PersistentEngineConfig {
            micro_batch_rows: 1,
            initial_depth: 3,
            adaptive: None,
        },
    )
    .unwrap();
    let good_a = input(3, 2, 0.0);
    let bad = Tensor::new(vec![3, 2], vec![-1234.5; 6]).unwrap();
    let good_b = input(3, 2, 100.0);

    let ha = engine.submit(&good_a).unwrap();
    let hbad = engine.submit(&bad).unwrap();
    let hb = engine.submit(&good_b).unwrap();

    assert_eq!(ha.wait().unwrap().output, good_a);
    let err = hbad.wait().unwrap_err();
    assert!(
        format!("{err:#}").contains("stage 1"),
        "failure must carry stage context, got: {err:#}"
    );
    let run_b = hb.wait().unwrap();
    assert_eq!(run_b.output, good_b);
    for c in &run_b.stage_counters {
        assert_eq!(
            c.micro_batches, 3,
            "stage {} lost micro-batches after the failure",
            c.stage
        );
    }
    // Still serving after the failure drained.
    assert_eq!(engine.run(&good_a).unwrap().output, good_a);
}

#[test]
fn adaptive_depth_converges_near_best_fixed_depth() {
    // Sweep fixed depths to find the knee (smallest depth within 2% of
    // the best cross-batch throughput), then check the controller parks
    // within one step of it.
    let n_batches = 10;
    let batches: Vec<Tensor> =
        (0..n_batches).map(|i| input(4, 4, i as f32)).collect();

    let mut best_ms = f64::INFINITY;
    let mut sweep: Vec<(usize, f64)> = Vec::new();
    for depth in 1..=6 {
        let engine = PersistentEngine::new(
            paper_stages(),
            PersistentEngineConfig {
                micro_batch_rows: 1,
                initial_depth: depth,
                adaptive: None,
            },
        )
        .unwrap();
        let handles: Vec<_> =
            batches.iter().map(|b| engine.submit(b).unwrap()).collect();
        for h in handles {
            h.wait().unwrap();
        }
        let ms = engine.makespan_ms();
        best_ms = best_ms.min(ms);
        sweep.push((depth, ms));
    }
    let best_depth = sweep
        .iter()
        .find(|(_, ms)| *ms <= best_ms * 1.02)
        .map(|(d, _)| *d)
        .unwrap();

    let engine = PersistentEngine::new(
        paper_stages(),
        PersistentEngineConfig {
            micro_batch_rows: 1,
            initial_depth: 1,
            adaptive: Some(AdaptiveDepthConfig {
                max_depth: 6,
                ..AdaptiveDepthConfig::default()
            }),
        },
    )
    .unwrap();
    // Longer run so the controller has batches to observe.
    let mut handles = Vec::new();
    for _round in 0..3 {
        for b in &batches {
            handles.push(engine.submit(b).unwrap());
        }
    }
    for h in handles {
        h.wait().unwrap();
    }
    let final_depth = engine.current_depth() as i64;
    assert!(
        (final_depth - best_depth as i64).abs() <= 1,
        "adaptive depth {final_depth} not within 1 of best fixed depth \
         {best_depth} (sweep: {sweep:?})"
    );
    let report = engine.depth_report();
    assert!(report.widenings >= 1, "controller never widened: {report:?}");
}

#[test]
fn streamed_serving_uses_persistent_engine_end_to_end() {
    require_artifacts!();
    let mut cfg = AmpConfig::paper_cluster_adaptive(&common::artifacts_dir(), 6);
    cfg.pipeline_depth = 2;
    cfg.monitor_interval_ms = 20;
    let server = EdgeServer::start(cfg).unwrap();
    let report = server.serve_workload(16, 16, Arrival::Closed, 7).unwrap();
    assert_eq!(report.metrics.completed, 16);
    assert_eq!(report.metrics.failed, 0);
    // The adaptive engine reported its trajectory and a live window.
    assert!(report.final_pipeline_depth >= 1);
    let depth = report.depth_report.expect("adaptive depth report");
    assert_eq!(depth.initial_depth, 2);
    assert!(depth.final_depth >= 1 && depth.final_depth <= 6);
    // Stage counters flowed through the persistent engine into the
    // report, and the scheduler drained every stage node.
    assert_eq!(report.stage_counters.len(), 3);
    for c in &report.stage_counters {
        assert!(c.micro_batches > 0);
    }
    let sched = server.scheduler.report();
    assert!(sched.active_tasks.iter().all(|(_, active)| *active == 0));
}
