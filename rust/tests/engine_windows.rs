//! ISSUE 3 tentpole tests: per-stage credit windows, batch coalescing,
//! and engine-aware rebalance — on the deterministic harness.
//!
//! Pins the equivalence properties (uniform budgets degenerate to the
//! PR-2 global window; coalesced submissions stay bit-identical and
//! batch-addressable), the fault-isolation guarantees (a stage panic
//! inside a coalesced transport fails only its member batches and
//! `BatchHandle::wait` never hangs), the backlog veto, and the
//! learned-budget carry that makes rebalance engine-aware.

mod common;

use common::harness as h;

use std::sync::Arc;

use amp4ec::config::AmpConfig;
use amp4ec::pipeline::engine::{
    budgets_from_profile, carry_stage_budgets, run_serial, AdaptiveDepthConfig,
    PersistentEngine, PersistentEngineConfig, SimStages,
};
use amp4ec::runtime::Tensor;
use amp4ec::server::EdgeServer;
use amp4ec::workload::Arrival;

// ---------------------------------------------------------------------------
// Equivalence: uniform per-stage budgets == the PR-2 global window
// ---------------------------------------------------------------------------

#[test]
fn uniform_stage_budgets_degenerate_to_global_window() {
    // Explicit per-stage budgets of [W, W, W] must reproduce the global
    // window W schedule *exactly*: same outputs, same per-batch sim
    // totals, same cross-batch makespan.
    let batches: Vec<Tensor> =
        (0..5).map(|i| h::seeded_input(4, 6, 40 + i)).collect();

    let global = h::engine(h::paper_stages(2.0), 3);
    let uniform = PersistentEngine::new(
        h::paper_stages(2.0),
        PersistentEngineConfig {
            micro_batch_rows: 1,
            initial_depth: 3,
            stage_budgets: Some(vec![3, 3, 3]),
            ..Default::default()
        },
    )
    .unwrap();

    let hg: Vec<_> = batches.iter().map(|b| global.submit(b).unwrap()).collect();
    let rg: Vec<_> = hg.into_iter().map(|hdl| hdl.wait().unwrap()).collect();
    let hu: Vec<_> = batches.iter().map(|b| uniform.submit(b).unwrap()).collect();
    let ru: Vec<_> = hu.into_iter().map(|hdl| hdl.wait().unwrap()).collect();

    for (g, u) in rg.iter().zip(&ru) {
        assert_eq!(g.output, u.output, "outputs diverged");
        assert!(
            (g.timing.total_ms - u.timing.total_ms).abs() < 1e-9,
            "per-batch totals diverged: global {} vs uniform {}",
            g.timing.total_ms,
            u.timing.total_ms
        );
    }
    assert!(
        (global.makespan_ms() - uniform.makespan_ms()).abs() < 1e-9,
        "makespans diverged: global {} vs uniform per-stage {}",
        global.makespan_ms(),
        uniform.makespan_ms()
    );
    assert_eq!(uniform.stage_budgets(), vec![3, 3, 3]);
    assert_eq!(uniform.current_depth(), 3);
}

// ---------------------------------------------------------------------------
// Per-stage budget shape beats a uniform split on a skewed chain
// ---------------------------------------------------------------------------

#[test]
fn shaped_budgets_beat_uniform_split_on_skewed_profile() {
    // 5 stages, bottleneck last: at the same total credit capacity, a
    // profile-shaped budget vector (small windows on the fast early
    // stages, a deep delivery window) keeps the bottleneck fed where
    // the equal split starves it. The bench pins the >= 10% acceptance
    // number; this is the deterministic floor.
    let batches: Vec<Tensor> =
        (0..10).map(|i| h::seeded_input(4, 16, 60 + i)).collect();

    // Probe one batch at the uniform window to measure the per-stage
    // latency profile (compute + ingress comm per micro-batch).
    let probe = h::engine(h::sim_stages(h::SKEWED_SHARES, 2.0), 2);
    let probe_run = probe.run(&batches[0]).unwrap();
    let latencies: Vec<f64> = probe_run
        .stage_counters
        .iter()
        .map(|c| (c.busy_ms + c.comm_ms) / c.micro_batches.max(1) as f64)
        .collect();
    drop(probe);

    let n_stages = h::SKEWED_SHARES.len();
    let uniform_depth = 2usize;
    let total_credits = uniform_depth * n_stages;
    let shaped = budgets_from_profile(&latencies, total_credits);
    assert_eq!(shaped.iter().sum::<usize>(), total_credits);
    assert!(
        *shaped.last().unwrap() > uniform_depth,
        "profile shaping should deepen the delivery window: {shaped:?}"
    );

    let run_all = |engine: &PersistentEngine| {
        let handles: Vec<_> =
            batches.iter().map(|b| engine.submit(b).unwrap()).collect();
        for hdl in handles {
            hdl.wait().unwrap();
        }
        engine.makespan_ms()
    };

    let uniform = h::engine(h::sim_stages(h::SKEWED_SHARES, 2.0), uniform_depth);
    let uniform_ms = run_all(&uniform);

    let per_stage = PersistentEngine::new(
        h::sim_stages(h::SKEWED_SHARES, 2.0),
        PersistentEngineConfig {
            micro_batch_rows: 1,
            initial_depth: *shaped.last().unwrap(),
            stage_budgets: Some(shaped.clone()),
            ..Default::default()
        },
    )
    .unwrap();
    let shaped_ms = run_all(&per_stage);

    assert!(
        shaped_ms * 1.05 < uniform_ms,
        "shaped budgets {shaped:?} ({shaped_ms:.1} ms) must beat the \
         uniform split of the same {total_credits} credits \
         ({uniform_ms:.1} ms) by >= 5%"
    );
}

// ---------------------------------------------------------------------------
// Coalescing: bit-identity, addressability, and stats
// ---------------------------------------------------------------------------

/// Build a coalescing engine at `micro` rows per micro-batch.
fn coalescing_engine(micro: usize, depth: usize) -> PersistentEngine {
    PersistentEngine::new(
        h::paper_stages(2.0),
        PersistentEngineConfig {
            micro_batch_rows: micro,
            initial_depth: depth,
            coalesce: true,
            ..Default::default()
        },
    )
    .unwrap()
}

#[test]
fn coalesced_submissions_stay_bit_identical_and_addressable() {
    let stages = h::paper_stages(2.0);
    // Merging depends on the small submissions being queued while the
    // feeder is still busy with the plug — near-certain with a 16-chunk
    // plug (tens of milliseconds of credit waits vs microsecond
    // submits), but a pathologically descheduled submitter could still
    // miss the window, so retry the scenario; bit-identity is asserted
    // on every attempt regardless.
    let mut coalesced = false;
    for attempt in 0..3 {
        let engine = coalescing_engine(4, 2);

        // The plug exhausts the credits (64 rows = 16 micro-batches at
        // depth 2) so the feeder is busy when the smalls arrive — they
        // queue behind it and become coalescing candidates. The plug is
        // a whole multiple of the micro-batch, so it never merges with
        // them itself.
        let plug = h::seeded_input(64, 6, 70 + attempt);
        let smalls: Vec<Tensor> =
            (0..4).map(|i| h::seeded_input(2, 6, 80 + i)).collect();

        let hp = engine.submit(&plug).unwrap();
        let hs: Vec<_> =
            smalls.iter().map(|b| engine.submit(b).unwrap()).collect();

        assert_eq!(
            hp.wait().unwrap().output,
            run_serial(&*stages, &plug, 4).unwrap().output
        );
        // Every member's rows come back re-split, in order, bit-identical
        // to an uncoalesced serial traversal of just that batch.
        for (b, hdl) in smalls.iter().zip(hs) {
            let run = hdl.wait().unwrap();
            assert_eq!(
                run.output,
                run_serial(&*stages, b, 4).unwrap().output,
                "coalesced member output diverged"
            );
            assert_eq!(run.output.shape[0], 2, "member rows not re-split");
        }

        let stats = engine.coalesce_stats();
        assert_eq!(stats.member_batches, 5, "{stats:?}");
        if stats.coalesced_transports >= 1 {
            assert!(stats.saved_micro_batches >= 1, "{stats:?}");
            coalesced = true;
            break;
        }
    }
    assert!(
        coalesced,
        "two 2-row submissions never packed into one 4-row micro-batch \
         in any attempt"
    );
}

#[test]
fn coalescing_disabled_never_merges() {
    let engine = PersistentEngine::new(
        h::paper_stages(2.0),
        PersistentEngineConfig {
            micro_batch_rows: 4,
            initial_depth: 2,
            coalesce: false,
            ..Default::default()
        },
    )
    .unwrap();
    let handles: Vec<_> = (0..4)
        .map(|i| engine.submit(&h::seeded_input(2, 6, 90 + i)).unwrap())
        .collect();
    for hdl in handles {
        hdl.wait().unwrap();
    }
    let stats = engine.coalesce_stats();
    assert_eq!(stats.coalesced_transports, 0);
    assert_eq!(stats.saved_micro_batches, 0);
    assert_eq!(stats.transports, stats.member_batches);
}

// ---------------------------------------------------------------------------
// Fault injection: panics, coalesced blast radius, drain on shutdown
// ---------------------------------------------------------------------------

#[test]
fn stage_panic_fails_batch_without_killing_engine() {
    let sent = 999.0f32;
    let sent_at_1 = sent * 1.5 + 0.25; // stage 0's row-wise transform
    let stages = Arc::new(
        h::FaultStages::new(SimStages::heterogeneous(&[1.0, 1.0, 1.0], 2.0))
            .panic_on(1, sent_at_1),
    );
    let engine = h::engine(Arc::clone(&stages), 2);
    let good = h::seeded_input(3, 4, 11);
    let bad = h::sentinel_input(3, 4, sent);

    let hg = engine.submit(&good).unwrap();
    let hb = engine.submit(&bad).unwrap();
    let hg2 = engine.submit(&good).unwrap();

    let want = run_serial(&*stages, &good, 1).unwrap().output;
    assert_eq!(hg.wait().unwrap().output, want);
    let err = hb.wait().unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("panicked") && msg.contains("stage 1"),
        "panic must surface as a stage-1 failure, got: {msg}"
    );
    // The drivers survived the panic: the following batch and fresh
    // submissions still complete.
    assert_eq!(hg2.wait().unwrap().output, want);
    assert_eq!(engine.run(&good).unwrap().output, want);
}

#[test]
fn panic_inside_coalesced_transport_fails_only_its_members() {
    let sent = 999.0f32;
    let sent_at_1 = sent * 1.5 + 0.25;
    let stages = Arc::new(
        h::FaultStages::new(SimStages::heterogeneous(&[1.0, 1.0, 1.0], 2.0))
            .panic_on(1, sent_at_1),
    );
    let engine = PersistentEngine::new(
        Arc::clone(&stages),
        PersistentEngineConfig {
            micro_batch_rows: 4,
            initial_depth: 2,
            coalesce: true,
            ..Default::default()
        },
    )
    .unwrap();

    // Plug (its own transport, in flight when the panic hits), then a
    // sentinel 2-row batch and a good 2-row batch that pack into one
    // 4-row micro-batch — sharing the panicking transport.
    let plug = h::seeded_input(32, 4, 12);
    let bad = h::sentinel_input(2, 4, sent);
    let buddy = h::seeded_input(2, 4, 13);

    let hp = engine.submit(&plug).unwrap();
    let hb = engine.submit(&bad).unwrap();
    let hbuddy = engine.submit(&buddy).unwrap();

    // The other in-flight transport completes untouched.
    assert_eq!(
        hp.wait().unwrap().output,
        run_serial(&*stages, &plug, 4).unwrap().output
    );
    // Every member of the panicking transport resolves with an error —
    // wait() never hangs.
    let err = hb.wait().unwrap_err();
    assert!(
        format!("{err:#}").contains("panicked"),
        "sentinel member must see the panic, got: {err:#}"
    );
    let buddy_result = hbuddy.wait();
    match engine.coalesce_stats().coalesced_transports {
        0 => {
            // Scheduling put the buddy in its own transport: it must
            // then complete normally.
            assert_eq!(
                buddy_result.unwrap().output,
                run_serial(&*stages, &buddy, 4).unwrap().output
            );
        }
        _ => {
            // Shared the sentinel's micro-batch: shares its fate, with
            // the coalesced context attached.
            let e = buddy_result.unwrap_err();
            assert!(
                format!("{e:#}").contains("coalesced transport failed"),
                "buddy member error missing context: {e:#}"
            );
        }
    }
    // The engine still serves after the panic drained.
    assert_eq!(
        engine.run(&plug).unwrap().output,
        run_serial(&*stages, &plug, 4).unwrap().output
    );
}

#[test]
fn engine_drop_mid_stream_drains_accepted_batches() {
    // Dropping the engine with work in flight (a rebalance swap does
    // exactly this to the old engine) must drain every accepted batch:
    // all handles resolve Ok with correct rows, none hang.
    let stages = h::paper_stages(2.0);
    let engine = PersistentEngine::new(
        Arc::clone(&stages),
        PersistentEngineConfig {
            micro_batch_rows: 1,
            initial_depth: 2,
            stage_budgets: Some(vec![1, 2, 3]),
            ..Default::default()
        },
    )
    .unwrap();
    let batches: Vec<Tensor> =
        (0..4).map(|i| h::seeded_input(3, 4, 20 + i)).collect();
    let handles: Vec<_> =
        batches.iter().map(|b| engine.submit(b).unwrap()).collect();
    drop(engine);
    for (b, hdl) in batches.iter().zip(handles) {
        let run = hdl.wait().expect("accepted batch must drain on drop");
        assert_eq!(run.output, run_serial(&*stages, b, 1).unwrap().output);
    }
}

// ---------------------------------------------------------------------------
// Adaptive controller: per-stage widening and the backlog veto
// ---------------------------------------------------------------------------

#[test]
fn per_stage_controller_widens_starved_windows() {
    let engine = PersistentEngine::new(
        h::paper_stages(2.0),
        PersistentEngineConfig {
            micro_batch_rows: 1,
            initial_depth: 1,
            per_stage: true,
            adaptive: Some(AdaptiveDepthConfig {
                max_depth: 6,
                ..AdaptiveDepthConfig::default()
            }),
            ..Default::default()
        },
    )
    .unwrap();
    let b = h::seeded_input(4, 4, 55);
    for _ in 0..10 {
        engine.run(&b).unwrap();
    }
    let report = engine.depth_report();
    assert!(
        report.widenings >= 1,
        "starved sequential batches must widen some window: {report:?}"
    );
    let budgets = engine.stage_budgets();
    assert!(
        budgets.iter().any(|&w| w >= 2),
        "no budget grew: {budgets:?}"
    );
    // Budgets resize independently: the controller grows the binding
    // windows, not the whole chain in lockstep.
    assert_eq!(budgets.len(), 3);
    assert_eq!(*budgets.last().unwrap(), engine.current_depth());
}

#[test]
fn backlog_veto_blocks_widening() {
    let build = || {
        let stages = Arc::new(h::FaultStages::new(
            SimStages::heterogeneous(h::PAPER_SHARES, 2.0),
        ));
        let engine = PersistentEngine::new(
            Arc::clone(&stages),
            PersistentEngineConfig {
                micro_batch_rows: 1,
                initial_depth: 1,
                adaptive: Some(AdaptiveDepthConfig {
                    max_depth: 6,
                    ..AdaptiveDepthConfig::default()
                }),
                ..Default::default()
            },
        )
        .unwrap();
        (stages, engine)
    };

    // Control: credit-starved sequential batches widen the window.
    let (_stages, engine) = build();
    let b = h::seeded_input(4, 4, 66);
    for _ in 0..8 {
        engine.run(&b).unwrap();
    }
    assert!(
        engine.depth_report().widenings >= 1,
        "control run never widened: {:?}",
        engine.depth_report()
    );

    // Same traffic, but the bottleneck node reports a deep wall-clock
    // backlog: its bubbles are device congestion, not credit starvation
    // — the `Executor::queue_depth` second signal vetoes widening.
    let (stages, engine) = build();
    stages.set_backlog(2, 100); // stage 2 (0.4 CPU) is the bottleneck
    for _ in 0..8 {
        engine.run(&b).unwrap();
    }
    let report = engine.depth_report();
    assert_eq!(
        report.widenings, 0,
        "backlogged bottleneck must veto widening: {report:?}"
    );
    assert_eq!(engine.current_depth(), 1);
}

// ---------------------------------------------------------------------------
// Engine-aware rebalance: learned budgets carry into the rebuilt engine
// ---------------------------------------------------------------------------

#[test]
fn carry_stage_budgets_preserves_shape() {
    assert_eq!(carry_stage_budgets(&[1, 2, 4], 3), vec![1, 2, 4]);
    // Shrinking keeps the first and delivery budgets.
    assert_eq!(carry_stage_budgets(&[1, 2, 4], 2), vec![1, 4]);
    // Growing repeats interior samples, monotone, delivery preserved.
    assert_eq!(carry_stage_budgets(&[2, 5], 4), vec![2, 2, 2, 5]);
    assert_eq!(carry_stage_budgets(&[3], 3), vec![3, 3, 3]);
    let carried = carry_stage_budgets(&[1, 1, 2, 3, 6], 3);
    assert_eq!(carried.len(), 3);
    assert_eq!(*carried.last().unwrap(), 6);
    assert!(carried.windows(2).all(|w| w[0] <= w[1]), "{carried:?}");
}

#[test]
fn budgets_from_profile_is_monotone_and_sums_to_target() {
    let w = budgets_from_profile(&[2.0, 2.0, 2.0, 2.0, 7.0], 10);
    assert_eq!(w.len(), 5);
    assert_eq!(w.iter().sum::<usize>(), 10);
    assert!(w.windows(2).all(|p| p[0] <= p[1]), "{w:?}");
    assert!(w.iter().all(|&b| b >= 1), "{w:?}");
    // Degenerate targets still give every stage a credit.
    let tiny = budgets_from_profile(&[1.0, 1.0, 1.0], 1);
    assert_eq!(tiny, vec![1, 1, 1]);
    // A flat profile spreads evenly.
    let flat = budgets_from_profile(&[3.0, 3.0], 4);
    assert_eq!(flat.iter().sum::<usize>(), 4);
}

#[test]
fn rebuilt_engine_starts_from_learned_budgets_not_defaults() {
    // Engine A learns a window shape under per-stage adaptive control;
    // engine B (the "rebuilt" engine after a rebalance) is seeded with
    // A's learned budgets and must *start* there — controller warm, not
    // cold.
    let a = PersistentEngine::new(
        h::paper_stages(2.0),
        PersistentEngineConfig {
            micro_batch_rows: 1,
            initial_depth: 1,
            per_stage: true,
            adaptive: Some(AdaptiveDepthConfig {
                max_depth: 6,
                ..AdaptiveDepthConfig::default()
            }),
            ..Default::default()
        },
    )
    .unwrap();
    let b = h::seeded_input(4, 4, 77);
    for _ in 0..10 {
        a.run(&b).unwrap();
    }
    let learned = a.stage_budgets();
    assert!(
        learned.iter().any(|&w| w >= 2),
        "engine A never learned anything: {learned:?}"
    );
    drop(a);

    let rebuilt = PersistentEngine::new(
        h::paper_stages(2.0),
        PersistentEngineConfig {
            micro_batch_rows: 1,
            initial_depth: *learned.last().unwrap(),
            stage_budgets: Some(learned.clone()),
            per_stage: true,
            adaptive: Some(AdaptiveDepthConfig {
                max_depth: 6,
                ..AdaptiveDepthConfig::default()
            }),
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(
        rebuilt.stage_budgets(),
        learned,
        "rebuilt engine did not start from the learned budgets"
    );
    assert_eq!(rebuilt.depth_report().initial_depth, *learned.last().unwrap());
    assert_eq!(rebuilt.depth_report().widenings, 0, "controller restarted");
    // And it serves correctly from the carried shape.
    let run = rebuilt.run(&b).unwrap();
    assert_eq!(
        run.output,
        run_serial(&*h::paper_stages(2.0), &b, 1).unwrap().output
    );
}

// ---------------------------------------------------------------------------
// Artifact-gated end-to-end: rebalance with per-stage windows active
// ---------------------------------------------------------------------------

fn windows_config() -> AmpConfig {
    let mut cfg = AmpConfig::paper_cluster_adaptive(&common::artifacts_dir(), 6);
    cfg.pipeline_depth = 2;
    cfg.per_stage_windows = true;
    cfg.coalesce = true;
    cfg.monitor_interval_ms = 20;
    cfg
}

#[test]
fn rebalance_carries_learned_windows_end_to_end() {
    require_artifacts!();
    let server = EdgeServer::start(windows_config()).unwrap();
    let report = server.serve_workload(16, 16, Arrival::Closed, 5).unwrap();
    assert_eq!(report.metrics.completed, 16);
    assert_eq!(report.metrics.failed, 0);
    let (before, coalesce) = {
        let svc = server.service();
        svc.window_status()
    };
    assert_eq!(before.len(), 3);
    assert!(coalesce.is_some());

    // Same topology (no node left), but the deployment and engine are
    // rebuilt — the fresh engine must seed from the learned budgets, not
    // restart at the configured depth.
    server.rebalance().unwrap();
    let (after, _) = server.service().window_status();
    assert_eq!(
        after, before,
        "rebuilt engine lost the learned per-stage budgets"
    );
    let report = server.serve_workload(8, 8, Arrival::Closed, 6).unwrap();
    assert_eq!(report.metrics.completed, 8);
    assert_eq!(report.metrics.failed, 0);
}

#[test]
fn rebalance_mid_stream_drains_cleanly_with_stage_windows() {
    require_artifacts!();
    let server = Arc::new(EdgeServer::start(windows_config()).unwrap());
    let n = 24;
    let srv = Arc::clone(&server);
    let serve = std::thread::spawn(move || {
        srv.serve_workload(n, n, Arrival::Closed, 9).unwrap()
    });
    // Rebalance while requests are in flight: the old engine must drain
    // its accepted batches against the old deployment before teardown —
    // no failures, no hangs.
    std::thread::sleep(std::time::Duration::from_millis(30));
    server.rebalance().unwrap();
    let report = serve.join().expect("serve thread");
    assert_eq!(report.metrics.completed, n as u64);
    assert_eq!(report.metrics.failed, 0);
    let sched = server.scheduler.report();
    assert!(sched.active_tasks.iter().all(|(_, active)| *active == 0));
}
