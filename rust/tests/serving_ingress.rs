//! ISSUE 4 tentpole tests: the unified request-level serving API.
//!
//! Pins the redesign's acceptance criteria on the deterministic
//! harness: default-class no-deadline traffic through the ingress is
//! bit-identical to the pre-redesign direct pipeline path; expired-
//! deadline requests are shed (reported, never hung) at both the
//! ingress and the engine feeder; high-priority requests meet deadlines
//! under a saturated engine that a best-effort-only run misses (the
//! engine held saturated via the harness's `FaultStages` backlog
//! injection, which vetoes adaptive widening); and the live-profile
//! window retune (`reshape_budgets` / `live_stage_latencies`) moves
//! budgets without draining the pipeline.

mod common;

use common::harness as h;

use std::sync::Arc;
use std::time::{Duration, Instant};

use amp4ec::cluster::NodeSnapshot;
use amp4ec::monitor::ClusterSnapshot;
use amp4ec::pipeline::engine::{
    run_serial, AdaptiveDepthConfig, DeadlineShed, PersistentEngine,
    PersistentEngineConfig, SimStages,
};
use amp4ec::runtime::Tensor;
use amp4ec::server::{live_stage_latencies, single_request, EdgeServer};
use amp4ec::serving::{
    EngineService, IngressConfig, Outcome, Priority, ServiceHandle,
    ShedReason,
};
use amp4ec::workload::{feed_with, Arrival, InputPool, RequestSpec};

fn row(cols: usize, seed: u64) -> Tensor {
    h::seeded_input(1, cols, seed)
}

fn ingress_over(
    engine: PersistentEngine,
    depth: usize,
    cfg: IngressConfig,
) -> ServiceHandle {
    ServiceHandle::new(
        Arc::new(EngineService::new(Arc::new(engine), 1, depth)),
        cfg,
        None,
    )
}

// ---------------------------------------------------------------------------
// Equivalence: default-class traffic is bit-identical to the direct path
// ---------------------------------------------------------------------------

#[test]
fn default_traffic_bit_identical_to_direct_pipeline() {
    // 24 single-row requests through the full ingress (batching,
    // padding, engine submission) must produce exactly the rows the
    // pre-redesign direct path (serial pipeline traversal of each
    // input) produces.
    let inputs: Vec<Tensor> = (0..24).map(|i| row(16, 900 + i)).collect();
    let direct = h::sim_stages(h::PAPER_SHARES, 1.0);
    let expected: Vec<Tensor> = inputs
        .iter()
        .map(|t| run_serial(&*direct, t, 1).unwrap().output)
        .collect();

    let engine =
        PersistentEngine::new(h::sim_stages(h::PAPER_SHARES, 1.0), h::engine_cfg(2))
            .unwrap();
    let handle = ingress_over(engine, 4, IngressConfig::default());
    let responses: Vec<_> = inputs
        .iter()
        .map(|t| handle.submit(t.clone()).unwrap())
        .collect();
    for (r, want) in responses.into_iter().zip(&expected) {
        let out = r.wait_output().unwrap();
        assert_eq!(&out, want, "ingress output diverged from direct path");
    }
    let m = handle.finish();
    assert_eq!(m.completed, 24);
    assert_eq!(m.failed, 0);
    assert_eq!(m.total_shed(), 0);
    // Default-class traffic lands in the NORMAL lane.
    let c = m.class(Priority::NORMAL.class()).unwrap();
    assert_eq!(c.completed, 24);
    assert_eq!(c.deadline_total, 0);
}

// ---------------------------------------------------------------------------
// Deadline shedding: ingress-level and engine-level, never hung
// ---------------------------------------------------------------------------

#[test]
fn expired_deadlines_shed_at_ingress_under_saturation() {
    // Saturate a slow serial engine with best-effort traffic, then push
    // deadline-carrying requests the backlog cannot possibly meet:
    // every one resolves as Shed — reported, never hung — and the
    // per-class metrics count them.
    let engine =
        PersistentEngine::new(h::sim_stages(&[1.0, 0.25], 2.0), h::engine_cfg(1))
            .unwrap();
    let handle = ingress_over(
        engine,
        1,
        IngressConfig {
            workers: 1,
            max_wait: Duration::from_millis(1),
            ..IngressConfig::default()
        },
    );
    let flood: Vec<_> = (0..12)
        .map(|i| {
            handle
                .request(row(8, 700 + i))
                .priority(Priority::BEST_EFFORT)
                .submit()
                .unwrap()
        })
        .collect();
    // Already-expired deadlines: shed at dispatch, no engine work.
    let doomed: Vec<_> = (0..4)
        .map(|i| {
            handle
                .request(row(8, 750 + i))
                .deadline(Duration::from_nanos(1))
                .submit()
                .unwrap()
        })
        .collect();
    for d in doomed {
        match d.wait() {
            Outcome::Shed(ShedReason::DeadlineExpired | ShedReason::PredictedMiss) => {}
            other => panic!("expected shed, got {other:?}"),
        }
    }
    for f in flood {
        f.wait_output().unwrap();
    }
    let m = handle.finish();
    assert_eq!(m.completed, 12);
    assert_eq!(m.failed, 0);
    assert_eq!(m.total_shed(), 4);
    assert_eq!(m.class(Priority::NORMAL.class()).unwrap().shed(), 4);
}

#[test]
fn engine_feeder_sheds_expired_deadline_pre_admission() {
    // Fill a depth-1 engine's feeder with slow same-class batches, then
    // submit a batch whose deadline expires while it waits in the
    // submission queue: the feeder sheds it with a DeadlineShed error
    // instead of spending credits — and the handle resolves.
    let engine =
        PersistentEngine::new(h::sim_stages(&[1.0, 0.25], 3.0), h::engine_cfg(1))
            .unwrap();
    let blockers: Vec<_> = (0..4)
        .map(|i| {
            engine
                .submit_owned_with(h::seeded_input(3, 8, 800 + i), 1, None)
                .unwrap()
        })
        .collect();
    // ~12 micro-batches of >= 12 ms bottleneck time queue ahead; 20 ms
    // cannot survive the wait.
    let doomed = engine
        .submit_owned_with(
            h::seeded_input(2, 8, 850),
            1,
            Some(Instant::now() + Duration::from_millis(20)),
        )
        .unwrap();
    let err = doomed.wait().expect_err("deadline must shed");
    assert!(
        err.downcast_ref::<DeadlineShed>().is_some(),
        "expected DeadlineShed, got {err:#}"
    );
    for b in blockers {
        b.wait().unwrap();
    }
}

#[test]
fn engine_feeder_admits_urgent_class_first() {
    // While the feeder is busy pushing a slow blocker through a depth-1
    // window, a best-effort and a high-priority submission queue up;
    // the high-priority one must be admitted — and therefore delivered
    // — first, despite arriving later.
    let engine = Arc::new(
        PersistentEngine::new(h::sim_stages(&[1.0, 0.4], 4.0), h::engine_cfg(1))
            .unwrap(),
    );
    let blocker = engine
        .submit_owned_with(h::seeded_input(3, 8, 860), 1, None)
        .unwrap();
    let best_effort = engine
        .submit_owned_with(h::seeded_input(2, 8, 861), 2, None)
        .unwrap();
    let urgent = engine
        .submit_owned_with(h::seeded_input(2, 8, 862), 0, None)
        .unwrap();

    let t0 = Instant::now();
    let done_at = Arc::new(std::sync::Mutex::new(Vec::<(&str, Duration)>::new()));
    std::thread::scope(|s| {
        let d1 = Arc::clone(&done_at);
        s.spawn(move || {
            best_effort.wait().unwrap();
            d1.lock().unwrap().push(("best-effort", t0.elapsed()));
        });
        let d2 = Arc::clone(&done_at);
        s.spawn(move || {
            urgent.wait().unwrap();
            d2.lock().unwrap().push(("urgent", t0.elapsed()));
        });
        blocker.wait().unwrap();
    });
    let order = done_at.lock().unwrap().clone();
    let pos = |label: &str| {
        order
            .iter()
            .position(|(l, _)| *l == label)
            .unwrap_or_else(|| panic!("{label} never completed: {order:?}"))
    };
    assert!(
        pos("urgent") < pos("best-effort"),
        "urgent batch did not jump the best-effort backlog: {order:?}"
    );
}

// ---------------------------------------------------------------------------
// Acceptance: priority meets deadlines a saturated best-effort run misses
// ---------------------------------------------------------------------------

/// A saturated serving stack: adaptive per-stage engine over a
/// `FaultStages`-wrapped skewed chain whose injected device backlog
/// vetoes widening, so the window stays pinned at depth 1 and the
/// bottleneck's queueing is real.
fn saturated_stack() -> ServiceHandle {
    let faulty = Arc::new(h::FaultStages::new(
        SimStages::heterogeneous(&[1.0, 0.25], 2.0),
    ));
    // Backlog injection: the bottleneck node reports more queued work
    // than any budget, so the adaptive controller's widen veto keeps
    // the window at 1 for the whole run.
    faulty.set_backlog(1, 1000);
    let engine = PersistentEngine::new(
        faulty,
        PersistentEngineConfig {
            micro_batch_rows: 1,
            initial_depth: 1,
            adaptive: Some(AdaptiveDepthConfig {
                max_depth: 8,
                ..AdaptiveDepthConfig::default()
            }),
            ..Default::default()
        },
    )
    .unwrap();
    ServiceHandle::new(
        Arc::new(EngineService::new(Arc::new(engine), 1, 1)),
        IngressConfig {
            workers: 1,
            max_wait: Duration::from_millis(1),
            ..IngressConfig::default()
        },
        None,
    )
}

const FLOOD: usize = 30;
const DEADLINE: Duration = Duration::from_millis(250);

#[test]
fn high_priority_meets_deadlines_saturated_best_effort_misses() {
    // Mixed run: a best-effort flood saturates the engine; four
    // high-priority requests with a 250 ms deadline arrive behind it
    // and must all meet it (they jump everything not yet dispatched).
    let handle = saturated_stack();
    let flood: Vec<_> = (0..FLOOD)
        .map(|i| {
            handle
                .request(row(8, 500 + i as u64))
                .priority(Priority::BEST_EFFORT)
                .submit()
                .unwrap()
        })
        .collect();
    let urgent: Vec<_> = (0..4)
        .map(|i| {
            handle
                .request(row(8, 580 + i))
                .priority(Priority::HIGH)
                .deadline(DEADLINE)
                .submit()
                .unwrap()
        })
        .collect();
    for u in urgent {
        match u.wait() {
            Outcome::Done(r) => assert_eq!(r.deadline_met, Some(true)),
            other => panic!("urgent request did not complete: {other:?}"),
        }
    }
    for f in flood {
        f.wait_output().unwrap();
    }
    let m = handle.finish();
    let hi = m.class(Priority::HIGH.class()).unwrap();
    assert_eq!(hi.completed, 4);
    assert_eq!(hi.deadline_total, 4);
    assert_eq!(
        hi.deadline_met, 4,
        "high-priority p99 blew the deadline: {:?} ms",
        hi.latency_summary().p99()
    );
    assert_eq!(hi.shed(), 0);
    let be = m.class(Priority::BEST_EFFORT.class()).unwrap();
    assert_eq!(be.completed as usize, FLOOD);

    // Control run: the same flood best-effort-only, every request
    // carrying the same deadline — the saturated tail cannot make it:
    // requests are shed (expired or predicted) and/or finish late.
    // Every handle still resolves.
    let control = saturated_stack();
    let rs: Vec<_> = (0..FLOOD)
        .map(|i| {
            control
                .request(row(8, 500 + i as u64))
                .priority(Priority::BEST_EFFORT)
                .deadline(DEADLINE)
                .submit()
                .unwrap()
        })
        .collect();
    for r in rs {
        let _ = r.wait(); // resolves: Done, Shed, or Failed — never hangs
    }
    let cm = control.finish();
    let be = cm.class(Priority::BEST_EFFORT.class()).unwrap();
    assert_eq!(
        be.completed + be.failed + be.shed(),
        FLOOD as u64,
        "every request must resolve"
    );
    assert_eq!(be.failed, 0);
    assert!(
        be.shed() > 0 || be.deadline_met < be.deadline_total,
        "a saturated best-effort-only run should miss the deadline the \
         high-priority class met: {be:?}"
    );
}

// ---------------------------------------------------------------------------
// Live-profile window retune
// ---------------------------------------------------------------------------

#[test]
fn reshape_budgets_moves_windows_in_place() {
    let engine = PersistentEngine::new(
        h::sim_stages(h::SKEWED_SHARES, 1.0),
        PersistentEngineConfig {
            micro_batch_rows: 1,
            initial_depth: 2,
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(engine.stage_budgets(), vec![2; 5]);
    engine.reshape_budgets(&[1, 1, 2, 3, 3]);
    assert_eq!(engine.stage_budgets(), vec![1, 1, 2, 3, 3]);
    assert_eq!(engine.current_depth(), 3);
    // The reshaped engine still serves, bit-identically.
    let input = h::seeded_input(6, 8, 870);
    let want = run_serial(&*h::sim_stages(h::SKEWED_SHARES, 1.0), &input, 1)
        .unwrap()
        .output;
    assert_eq!(engine.run(&input).unwrap().output, want);
    // Zero targets clamp to the >= 1 floor instead of wedging a window.
    engine.reshape_budgets(&[0, 0, 0, 0, 0]);
    assert_eq!(engine.stage_budgets(), vec![1; 5]);
    assert_eq!(engine.run(&input).unwrap().output, want);
}

#[test]
fn reshape_budgets_clamps_to_adaptive_range() {
    let engine = PersistentEngine::new(
        h::sim_stages(h::PAPER_SHARES, 1.0),
        h::adaptive_cfg(2, 4),
    )
    .unwrap();
    engine.reshape_budgets(&[100, 1, 100]);
    // min_depth defaults to 1 in AdaptiveDepthConfig; max is 4.
    let budgets = engine.stage_budgets();
    assert!(
        budgets.iter().all(|&b| (1..=4).contains(&b)),
        "budgets escaped the adaptive range: {budgets:?}"
    );
    assert_eq!(budgets[0], 4);
    assert_eq!(budgets[2], 4);
}

#[test]
fn live_stage_latencies_scale_with_node_load() {
    // Serve some traffic so every stage has a measured profile, then
    // check the monitor-snapshot scaling: a loaded node's stage weighs
    // heavier, and a cold engine yields None.
    let engine = PersistentEngine::new(
        h::sim_stages(h::PAPER_SHARES, 2.0),
        h::engine_cfg(2),
    )
    .unwrap();
    let idle_snapshot = |loads: &[f64]| ClusterSnapshot {
        t_ms: 0.0,
        nodes: loads
            .iter()
            .enumerate()
            .map(|(id, &load)| NodeSnapshot {
                id,
                name: format!("sim-{id}"),
                online: true,
                cpu_fraction: 1.0,
                mem_limit_mb: 1024.0,
                current_load: load,
                mem_used_mb: 0.0,
                mem_pct: 0.0,
                rx_bytes: 0,
                tx_bytes: 0,
                tasks_completed: 0,
                tasks_failed: 0,
                stability: 1.0,
                link_latency_ms: 1.0,
            })
            .collect(),
    };
    // Cold engine: no profile yet.
    assert!(live_stage_latencies(
        &engine.total_counters(),
        &idle_snapshot(&[0.0, 0.0, 0.0])
    )
    .is_none());

    engine.run(&h::seeded_input(4, 8, 880)).unwrap();
    let idle =
        live_stage_latencies(&engine.total_counters(), &idle_snapshot(&[0.0, 0.0, 0.0]))
            .unwrap();
    assert_eq!(idle.len(), 3);
    assert!(idle.iter().all(|&ms| ms > 0.0));
    // Load node 1 to 100%: its stage latency doubles, others unchanged.
    let loaded =
        live_stage_latencies(&engine.total_counters(), &idle_snapshot(&[0.0, 1.0, 0.0]))
            .unwrap();
    assert!((loaded[0] - idle[0]).abs() < 1e-9);
    assert!((loaded[1] - 2.0 * idle[1]).abs() < 1e-9);
    assert!((loaded[2] - idle[2]).abs() < 1e-9);
}

// ---------------------------------------------------------------------------
// Artifact-gated: the real-model entry points ride the same ingress
// ---------------------------------------------------------------------------

#[test]
fn single_request_and_handle_agree_on_real_model() {
    require_artifacts!();
    let cfg = amp4ec::config::AmpConfig::paper_cluster(&common::artifacts_dir());
    let server = EdgeServer::start(cfg).unwrap();
    let pool = InputPool::new(&server.request_shape(), 2, 42);

    // The one-shot convenience path and an explicit serve handle must
    // produce bit-identical outputs for the same input (both are the
    // same ingress + pipeline).
    let (via_single, ms) = single_request(&server, pool.get(0)).unwrap();
    assert!(ms > 0.0);
    let handle = server.serve_handle();
    let via_handle = handle
        .request(pool.get(0).clone())
        .priority(Priority::HIGH)
        .deadline(Duration::from_secs(60))
        .submit()
        .unwrap()
        .wait_output()
        .unwrap();
    assert_eq!(via_single, via_handle);
    let m = handle.finish();
    let hi = m.class(Priority::HIGH.class()).unwrap();
    assert_eq!(hi.completed, 1);
    assert_eq!(hi.deadline_met, 1);
}

#[test]
fn mixed_class_workload_on_real_model() {
    require_artifacts!();
    let mut cfg = amp4ec::config::AmpConfig::paper_cluster(&common::artifacts_dir());
    cfg.monitor_interval_ms = 20;
    let server = EdgeServer::start(cfg).unwrap();
    let pool = InputPool::new(&server.request_shape(), 4, 9);
    let handle = server.serve_handle();
    let sent = feed_with(&handle, &pool, 8, Arrival::Closed, 5, |i| {
        if i % 2 == 0 {
            RequestSpec::new(Priority::HIGH)
                .with_deadline(Duration::from_secs(120))
        } else {
            RequestSpec::new(Priority::BEST_EFFORT)
        }
    });
    assert_eq!(sent, 8);
    let m = handle.finish();
    assert_eq!(m.completed, 8);
    assert_eq!(m.failed, 0);
    let hi = m.class(Priority::HIGH.class()).unwrap();
    assert_eq!(hi.completed, 4);
    assert_eq!(hi.deadline_met, 4);
}
