//! Streaming engine over the real artifacts: bit-identity against the
//! serial pipeline, the simulated-total regression, and end-to-end
//! streamed serving.

mod common;

use amp4ec::config::AmpConfig;
use amp4ec::pipeline::{self, engine};
use amp4ec::server::EdgeServer;
use amp4ec::workload::{Arrival, InputPool};

/// Deploy the manifest at batch 1 over the paper's heterogeneous trio
/// (the harness's canned deployment).
fn deploy_paper_cluster() -> (
    amp4ec::deployer::Deployment,
    std::sync::Arc<amp4ec::deployer::ModelDeployer>,
) {
    common::harness::deploy_paper_cluster(&common::artifacts_dir())
}

#[test]
fn serial_total_is_simulated_sum_of_components() {
    require_artifacts!();
    let (dep, deployer) = deploy_paper_cluster();
    let manifest = deployer.manifest();
    let input = InputPool::new(
        &[1, manifest.input_hw, manifest.input_hw, manifest.input_channels],
        1,
        11,
    );
    let (_, timing) = pipeline::run(&dep, input.get(0)).unwrap();
    // The ISSUE-1 regression: total_ms is the simulated critical path,
    // which for a serial run is exactly compute + comm — never host
    // wall-clock.
    assert!(
        (timing.total_ms - (timing.compute_ms + timing.comm_ms)).abs() < 1e-6,
        "total {} != compute {} + comm {}",
        timing.total_ms,
        timing.compute_ms,
        timing.comm_ms
    );
    assert_eq!(timing.stages.len(), 3);
    assert!(timing.compute_ms > 0.0 && timing.comm_ms > 0.0);
    deployer.undeploy(&dep);
}

#[test]
fn streamed_outputs_bit_identical_to_serial_pipeline() {
    require_artifacts!();
    let (dep, deployer) = deploy_paper_cluster();
    let manifest = deployer.manifest();
    let shape =
        [1, manifest.input_hw, manifest.input_hw, manifest.input_channels];
    let pool = InputPool::new(&shape, 4, 23);
    let inputs: Vec<_> = (0..4).map(|i| pool.get(i)).collect();
    let super_batch = {
        let mut chunks = Vec::new();
        for t in &inputs {
            chunks.push((*t).clone());
        }
        engine::concat_rows(&chunks).unwrap()
    };

    // Streamed: 4 micro-batches of the compiled batch (1 row) in flight.
    let cfg = engine::EngineConfig { micro_batch_rows: 1, max_in_flight: 4 };
    let streamed = engine::run_streamed(
        &engine::DeploymentStages::new(&dep),
        &super_batch,
        &cfg,
    )
    .unwrap();

    // Serial comparator: each row through `pipeline::run` on the same
    // deployment (same executables, same inputs).
    let mut serial_rows = Vec::new();
    for t in &inputs {
        let (out, _) = pipeline::run(&dep, t).unwrap();
        serial_rows.push(out);
    }
    let serial = engine::concat_rows(&serial_rows).unwrap();

    assert_eq!(
        streamed.output, serial,
        "streamed output must be bit-identical to serial pipeline::run"
    );
    // The engine overlapped stages: simulated makespan beats the serial
    // sum of the same per-stage work.
    let serial_sum: f64 = streamed.timing.compute_ms + streamed.timing.comm_ms;
    assert!(
        streamed.timing.total_ms <= serial_sum + 1e-6,
        "makespan {} cannot exceed serial sum {}",
        streamed.timing.total_ms,
        serial_sum
    );
    deployer.undeploy(&dep);
}

#[test]
fn streamed_serving_end_to_end() {
    require_artifacts!();
    let mut cfg = AmpConfig::paper_cluster_streamed(&common::artifacts_dir(), 4);
    cfg.monitor_interval_ms = 20;
    let server = EdgeServer::start(cfg).unwrap();
    let report = server.serve_workload(8, 8, Arrival::Closed, 31).unwrap();
    assert_eq!(report.metrics.completed, 8);
    assert_eq!(report.metrics.failed, 0);
    assert!(report.metrics.throughput_rps() > 0.0);
    // Per-stage engine counters made it into the report.
    assert_eq!(report.stage_counters.len(), 3);
    for c in &report.stage_counters {
        assert!(c.busy_ms > 0.0, "stage {} never computed", c.stage);
        assert!(c.micro_batches > 0);
    }
    // Every stage node was charged for the batches (Eq. 8 fix): the
    // scheduler saw completions on all three nodes.
    let sched_report = server.scheduler.report();
    assert_eq!(sched_report.avg_exec_ms.len(), 3);
    assert!(sched_report
        .active_tasks
        .iter()
        .all(|(_, active)| *active == 0));
}

#[test]
fn golden_parity_survives_streaming_config() {
    require_artifacts!();
    let mut cfg = AmpConfig::paper_cluster_streamed(&common::artifacts_dir(), 4);
    cfg.monitor_interval_ms = 20;
    let server = EdgeServer::start(cfg).unwrap();
    let diff = server.golden_check().unwrap();
    assert!(diff < 1e-2, "diff {diff}");
}
