//! Cluster-behavior integration tests: memory pressure, failure
//! injection, open-loop (Poisson) arrivals, and energy accounting on the
//! real serving stack.

mod common;

use std::sync::Arc;

use amp4ec::config::AmpConfig;
use amp4ec::server::{single_request, EdgeServer};
use amp4ec::workload::{Arrival, InputPool};

fn base_config() -> AmpConfig {
    let mut cfg = AmpConfig::paper_cluster(&common::artifacts_dir());
    cfg.monitor_interval_ms = 20;
    cfg
}

#[test]
fn memory_pressure_slows_inference() {
    require_artifacts!();
    // Same CPU everywhere; the second cluster's memory limit sits below
    // the runtime overhead + working set, so the paging penalty engages.
    let mut roomy = base_config();
    roomy.nodes.truncate(1);
    roomy.nodes[0].cpu = 1.0;
    roomy.nodes[0].mem_mb = 2048.0;
    let mut tight = base_config();
    tight.nodes.truncate(1);
    tight.nodes[0].cpu = 1.0;
    tight.nodes[0].mem_mb = 300.0; // below the 384 MB runtime overhead

    let measure = |cfg: AmpConfig| -> f64 {
        let server = EdgeServer::start(cfg).unwrap();
        let pool = InputPool::new(&server.request_shape(), 2, 5);
        single_request(&server, pool.get(0)).unwrap(); // warm
        let mut total = 0.0;
        for i in 0..5 {
            total += single_request(&server, pool.get(i)).unwrap().1;
        }
        total / 5.0
    };
    let fast = measure(roomy);
    let slow = measure(tight);
    assert!(
        slow > fast * 1.5,
        "paging penalty should slow the tight node: {fast:.1} vs {slow:.1} ms"
    );
}

#[test]
fn failure_injection_degrades_stability_not_liveness() {
    require_artifacts!();
    let mut cfg = base_config();
    // One flaky node in the pipeline fails ~30% of executions.
    cfg.nodes[1].fail_rate = 0.3;
    let server = EdgeServer::start(cfg).unwrap();
    let report = server.serve_workload(12, 12, Arrival::Closed, 6).unwrap();
    // Some requests fail (the pipeline surfaces the error)...
    assert!(report.metrics.failed > 0, "failure injection had no effect");
    // ...but the system keeps serving and the monitor sees the instability.
    assert!(report.metrics.completed > 0);
    let snapshot = server.monitor.latest().unwrap();
    let flaky = snapshot
        .nodes
        .iter()
        .find(|n| n.name == "edge-med")
        .unwrap();
    assert!(flaky.stability < 1.0, "stability {}", flaky.stability);
}

#[test]
fn poisson_open_loop_arrivals_serve_cleanly() {
    require_artifacts!();
    let server = EdgeServer::start(base_config()).unwrap();
    let report = server
        .serve_workload(10, 10, Arrival::Poisson { rate_rps: 20.0 }, 7)
        .unwrap();
    assert_eq!(report.metrics.completed, 10);
    assert_eq!(report.metrics.failed, 0);
    // Open-loop latency at a sustainable rate is far below the closed-loop
    // queue-saturated latency.
    assert!(report.metrics.mean_latency_ms() < 5000.0);
}

#[test]
fn energy_accounting_tracks_work() {
    require_artifacts!();
    let server = Arc::new(EdgeServer::start(base_config()).unwrap());
    let before: f64 = server
        .cluster
        .online_nodes()
        .iter()
        .map(|n| n.energy().compute_j)
        .sum();
    server.serve_workload(6, 6, Arrival::Closed, 8).unwrap();
    let after: f64 = server
        .cluster
        .online_nodes()
        .iter()
        .map(|n| n.energy().compute_j)
        .sum();
    assert!(after > before, "serving must burn compute energy");
    // Network energy is accounted from link counters too.
    let net: f64 = server
        .cluster
        .online_nodes()
        .iter()
        .map(|n| n.energy().network_j)
        .sum();
    assert!(net > 0.0);
}

#[test]
fn calibration_reports_all_blocks() {
    require_artifacts!();
    let m = amp4ec::manifest::Manifest::load(&common::artifacts_dir()).unwrap();
    let costs = amp4ec::server::calibrate_block_costs(&m, 1).unwrap();
    assert_eq!(costs.len(), m.blocks.len());
    assert!(costs.iter().all(|c| *c > 0.0));
    // The classifier block dominates at batch 1 (the §Perf finding that
    // motivated profile-guided partitioning).
    let total: f64 = costs.iter().sum();
    assert!(
        costs[19] / total > 0.2,
        "classifier share {:.2} unexpectedly small",
        costs[19] / total
    );
}
