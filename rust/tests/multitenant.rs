//! ISSUE 9: multi-tenant WFQ ingress properties.
//!
//! A gate-blocked single-worker recording service captures the exact
//! order the ingress dequeues requests, with each request's tenant
//! encoded in its input values. With a full two-tenant backlog formed
//! behind the closed gate, the observed service shares must track the
//! configured weights (±10%), a zero-weight tenant must be
//! deprioritized but never starved (the quantum floor), and with one
//! (or no) tenant configured the within-class order must be the plain
//! FIFO the single-tenant path has always used. Config-level coverage:
//! tenant tables survive a JSON round-trip and `validate()` rejects
//! malformed weight tables.

use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use amp4ec::config::{AmpConfig, TenantConfig};
use amp4ec::router::InferenceService;
use amp4ec::runtime::Tensor;
use amp4ec::serving::{IngressConfig, ServiceHandle};

type Gate = Arc<(Mutex<bool>, Condvar)>;
type Seen = Arc<Mutex<Vec<usize>>>;

/// Single-row input whose every element encodes `value` — the recorder
/// reads it back out to identify the request's tenant (or rank).
fn tagged(value: usize) -> Tensor {
    Tensor::new(vec![1, 4], vec![value as f32; 4]).unwrap()
}

/// Identity service that blocks every call until the gate opens, then
/// records the first element of each batch it serves — the dequeue
/// order, since a single worker serializes dispatch.
struct Recorder {
    gate: Gate,
    seen: Seen,
}

impl Recorder {
    fn new() -> (Recorder, Gate, Seen) {
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        let seen = Arc::new(Mutex::new(Vec::new()));
        let r = Recorder {
            gate: Arc::clone(&gate),
            seen: Arc::clone(&seen),
        };
        (r, gate, seen)
    }
}

fn open_gate(gate: &Gate) {
    let (lock, cv) = &**gate;
    *lock.lock().unwrap() = true;
    cv.notify_all();
}

impl InferenceService for Recorder {
    fn infer_batch(&self, batch: &Tensor) -> anyhow::Result<(Tensor, f64, f64)> {
        let (lock, cv) = &*self.gate;
        let mut open = lock.lock().unwrap();
        while !*open {
            open = cv.wait(open).unwrap();
        }
        drop(open);
        self.seen.lock().unwrap().push(batch.data()[0] as usize);
        Ok((batch.clone(), 0.0, 0.0))
    }
    fn batch_size(&self) -> usize {
        1
    }
    fn model_id(&self) -> u64 {
        0x7E57
    }
}

fn wfq_handle(weights: Vec<f64>) -> (ServiceHandle, Gate, Seen) {
    let (recorder, gate, seen) = Recorder::new();
    let handle = ServiceHandle::new(
        Arc::new(recorder),
        IngressConfig {
            workers: 1,
            max_wait: Duration::ZERO,
            tenant_weights: weights,
            ..IngressConfig::default()
        },
        None,
    );
    (handle, gate, seen)
}

#[test]
fn wfq_shares_track_weights_under_two_tenant_flood() {
    // 30 requests per tenant backlog behind the closed gate; with
    // weights 3:1 the dequeue order while both stay backlogged must
    // give tenant 0 ~75% of the service slots. Tenant 0 drains after
    // 40 dequeues, so the 40-dequeue prefix is the contested window.
    let (handle, gate, seen) = wfq_handle(vec![3.0, 1.0]);
    let mut pending = Vec::new();
    for _ in 0..30 {
        for t in 0..2 {
            pending.push(
                handle.request(tagged(t)).tenant(t).submit().unwrap(),
            );
        }
    }
    open_gate(&gate);
    for p in pending {
        p.wait_output().unwrap();
    }
    let m = handle.finish();
    assert_eq!(m.completed, 60);
    assert_eq!(m.tenant_completed(0), 30);
    assert_eq!(m.tenant_completed(1), 30);

    let order = seen.lock().unwrap().clone();
    assert_eq!(order.len(), 60);
    let contested = &order[..40];
    let share0 = contested.iter().filter(|&&t| t == 0).count() as f64 / 40.0;
    assert!(
        (share0 - 0.75).abs() <= 0.10,
        "tenant 0 served {share0} of the contested window, want ~0.75 \
         (order prefix: {:?})",
        &order[..20]
    );
}

#[test]
fn zero_weight_tenant_is_deprioritized_not_starved() {
    // A zero-weight tenant accrues the MIN_QUANTUM floor: far below an
    // equal share, but it must still be served while backlogged.
    let (handle, gate, seen) = wfq_handle(vec![1.0, 0.0]);
    let mut pending = Vec::new();
    for _ in 0..40 {
        for t in 0..2 {
            pending.push(
                handle.request(tagged(t)).tenant(t).submit().unwrap(),
            );
        }
    }
    open_gate(&gate);
    for p in pending {
        p.wait_output().unwrap();
    }
    let m = handle.finish();
    assert_eq!(m.completed, 80);

    let order = seen.lock().unwrap().clone();
    let contested = &order[..40];
    let served1 = contested.iter().filter(|&&t| t == 1).count();
    assert!(
        (1..=8).contains(&served1),
        "zero-weight tenant served {served1} of 40 contested slots; \
         want the quantum floor (>= 1) without a real share (<= 8)"
    );
}

#[test]
fn single_tenant_order_is_plain_fifo() {
    // The degeneracy guarantee: with no weight table (and with a
    // trivial single-entry one) the within-class order is submission
    // order, exactly as before tenancy existed.
    for weights in [Vec::new(), vec![1.0]] {
        let (handle, gate, seen) = wfq_handle(weights.clone());
        let pending: Vec<_> = (0..20)
            .map(|i| handle.request(tagged(i)).submit().unwrap())
            .collect();
        open_gate(&gate);
        for p in pending {
            p.wait_output().unwrap();
        }
        let m = handle.finish();
        assert_eq!(m.completed, 20);
        assert_eq!(m.tenant_completed(0), 20);
        let order = seen.lock().unwrap().clone();
        assert_eq!(
            order,
            (0..20).collect::<Vec<_>>(),
            "weights {weights:?} must keep plain FIFO order"
        );
    }
}

#[test]
fn tenant_config_round_trips_through_json_file() {
    let cfg = AmpConfig {
        tenants: vec![
            TenantConfig::new("gold", 3.0),
            TenantConfig::new("free", 1.0),
        ],
        ..AmpConfig::default()
    };
    cfg.validate().unwrap();

    let path = std::env::temp_dir()
        .join(format!("amp4ec-tenants-{}.json", std::process::id()));
    cfg.save(&path).unwrap();
    let loaded = AmpConfig::load(&path).unwrap();
    let _ = std::fs::remove_file(&path);

    assert_eq!(loaded.tenants, cfg.tenants);
    assert_eq!(loaded.tenant_weights(), vec![3.0, 1.0]);
    let table = loaded.tenant_table();
    assert_eq!(table.resolve("free"), Some(1));
    assert!(!table.is_trivial());
}

#[test]
fn validate_rejects_malformed_tenant_tables() {
    let base = AmpConfig::default();
    assert!(base.validate().is_ok(), "no tenants is the valid default");

    let with = |tenants: Vec<TenantConfig>| {
        AmpConfig {
            tenants,
            ..AmpConfig::default()
        }
        .validate()
    };
    // Empty name.
    assert!(with(vec![TenantConfig::new("", 1.0)]).is_err());
    assert!(with(vec![TenantConfig::new("  ", 1.0)]).is_err());
    // Negative / non-finite weight.
    assert!(with(vec![TenantConfig::new("a", -1.0)]).is_err());
    assert!(with(vec![TenantConfig::new("a", f64::NAN)]).is_err());
    // All-zero weights leave no share to divide.
    assert!(
        with(vec![
            TenantConfig::new("a", 0.0),
            TenantConfig::new("b", 0.0),
        ])
        .is_err()
    );
    // Duplicate names.
    assert!(
        with(vec![
            TenantConfig::new("a", 1.0),
            TenantConfig::new("a", 2.0),
        ])
        .is_err()
    );
    // A zero weight alongside a positive one is fine (floor, not
    // starvation), as is a standard table.
    assert!(
        with(vec![
            TenantConfig::new("gold", 3.0),
            TenantConfig::new("free", 0.0),
        ])
        .is_ok()
    );
}
