//! Runtime integration: HLO artifacts load, execute, and match the
//! python-recorded goldens — the AOT bridge parity signal.

mod common;

use amp4ec::manifest::Manifest;
use amp4ec::runtime::{Executor, Tensor, XlaRuntime};

#[test]
fn monolithic_matches_golden() {
    require_artifacts!();
    let m = Manifest::load(&common::artifacts_dir()).unwrap();
    let golden = m.golden.as_ref().unwrap();
    let mono = m.monolithic.as_ref().unwrap();

    let rt = XlaRuntime::cpu().unwrap();
    let exe = rt
        .load_hlo(&m.dir.join(&mono.artifacts[&golden.batch]))
        .unwrap();
    let weights = Tensor::from_f32_file(
        &m.dir.join(&mono.weights_file),
        vec![m.total_params as usize],
    )
    .unwrap();
    let input =
        Tensor::from_f32_file(&m.dir.join(&golden.input_file), golden.in_shape.clone())
            .unwrap();
    let want =
        Tensor::from_f32_file(&m.dir.join(&golden.output_file), golden.out_shape.clone())
            .unwrap();

    let out = exe
        .run(&[&weights, &input], &golden.out_shape)
        .unwrap();
    let diff = out.max_abs_diff(&want);
    assert!(
        (diff as f64) <= golden.tolerance,
        "monolithic vs golden diff {diff}"
    );
}

#[test]
fn block_chain_matches_golden() {
    require_artifacts!();
    let m = Manifest::load(&common::artifacts_dir()).unwrap();
    let golden = m.golden.as_ref().unwrap();
    let rt = XlaRuntime::cpu().unwrap();

    let mut act =
        Tensor::from_f32_file(&m.dir.join(&golden.input_file), golden.in_shape.clone())
            .unwrap();
    for b in &m.blocks {
        let exe = rt.load_hlo(&m.artifact_path(b, golden.batch).unwrap()).unwrap();
        let w = Tensor::from_f32_file(
            &m.weights_path(b),
            vec![b.param_count as usize],
        )
        .unwrap();
        let out_shape = if b.name == "classifier" {
            vec![golden.batch, m.num_classes]
        } else {
            vec![golden.batch, b.out_shape[0], b.out_shape[1], b.out_shape[2]]
        };
        act = exe.run(&[&w, &act], &out_shape).unwrap();
    }
    let want =
        Tensor::from_f32_file(&m.dir.join(&golden.output_file), golden.out_shape.clone())
            .unwrap();
    let diff = act.max_abs_diff(&want);
    // Chained per-block execution accumulates float reassociation noise;
    // allow a small multiple of the recorded tolerance.
    assert!(
        (diff as f64) <= golden.tolerance * 10.0,
        "block chain vs golden diff {diff}"
    );
}

#[test]
fn executor_thread_runs_blocks() {
    require_artifacts!();
    let m = Manifest::load(&common::artifacts_dir()).unwrap();
    let exec = Executor::spawn("itest").unwrap();
    let b0 = &m.blocks[0];
    let h = exec
        .load_block(
            m.artifact_path(b0, 1).unwrap(),
            m.weights_path(b0),
            b0.param_count as usize,
            vec![1, b0.out_shape[0], b0.out_shape[1], b0.out_shape[2]],
        )
        .unwrap();
    let input = Tensor::zeros(vec![1, b0.in_shape[0], b0.in_shape[1], b0.in_shape[2]]);
    let (out, host_ms) = exec.run_chain(vec![h], input).unwrap();
    assert_eq!(out.shape, vec![1, b0.out_shape[0], b0.out_shape[1], b0.out_shape[2]]);
    assert!(host_ms > 0.0);
    assert!(out.data().iter().all(|v| v.is_finite()));
    // ReLU6 epilogue bounds the stem output.
    assert!(out.data().iter().all(|&v| (0.0..=6.0).contains(&v)));
    exec.unload_block(h);
    // Running an unloaded block fails cleanly.
    let input2 = Tensor::zeros(vec![1, b0.in_shape[0], b0.in_shape[1], b0.in_shape[2]]);
    assert!(exec.run_chain(vec![h], input2).is_err());
}

#[test]
fn batch8_artifacts_execute() {
    require_artifacts!();
    let m = Manifest::load(&common::artifacts_dir()).unwrap();
    if !m.batch_sizes.contains(&8) {
        eprintln!("SKIP: no batch-8 artifacts");
        return;
    }
    let rt = XlaRuntime::cpu().unwrap();
    let b0 = &m.blocks[0];
    let exe = rt.load_hlo(&m.artifact_path(b0, 8).unwrap()).unwrap();
    let w = Tensor::from_f32_file(&m.weights_path(b0), vec![b0.param_count as usize])
        .unwrap();
    let x = Tensor::zeros(vec![8, b0.in_shape[0], b0.in_shape[1], b0.in_shape[2]]);
    let out = exe
        .run(&[&w, &x], &[8, b0.out_shape[0], b0.out_shape[1], b0.out_shape[2]])
        .unwrap();
    assert_eq!(out.shape[0], 8);
}

#[test]
fn device_resident_weights_path_matches_literal_path() {
    require_artifacts!();
    let m = Manifest::load(&common::artifacts_dir()).unwrap();
    let rt = XlaRuntime::cpu().unwrap();
    let b = &m.blocks[1];
    let exe = rt.load_hlo(&m.artifact_path(b, 1).unwrap()).unwrap();
    let w = Tensor::from_f32_file(&m.weights_path(b), vec![b.param_count as usize])
        .unwrap();
    let mut x = Tensor::zeros(vec![1, b.in_shape[0], b.in_shape[1], b.in_shape[2]]);
    for (i, v) in x.data_mut().iter_mut().enumerate() {
        *v = ((i % 13) as f32 - 6.0) / 6.0;
    }
    let out_shape = vec![1, b.out_shape[0], b.out_shape[1], b.out_shape[2]];
    let via_literals = exe.run(&[&w, &x], &out_shape).unwrap();
    let wbuf = rt.upload(&w).unwrap();
    let xbuf = rt.upload(&x).unwrap();
    let via_buffers = exe.run_with_weights(&wbuf, &xbuf, &out_shape).unwrap();
    assert_eq!(via_literals, via_buffers);
}
