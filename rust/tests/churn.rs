//! Self-healing serving under node churn (ISSUE 8).
//!
//! Engine level: a replica of a replicated stage dies mid-stream with
//! micro-batches in flight. With replay on, the driver re-runs the
//! failed micro-batches on surviving replicas and the batch completes
//! bit-identically to the serial schedule; with replay off, the same
//! kill schedule reproduces the pre-heal fail-fast behaviour (pinned
//! here so healing stays strictly opt-in).
//!
//! Server level (artifact-gated): the heal watchdog consumes the
//! monitor's liveness feed and walks the heal ladder — replica
//! re-placement when every stage keeps a survivor, full re-partition
//! when one does not — while the serving ingress retries batches that
//! raced the swap. Every response handle must resolve either way.

mod common;

use std::sync::Arc;
use std::time::{Duration, Instant};

use amp4ec::config::AmpConfig;
use amp4ec::pipeline::engine::{
    run_serial, PersistentEngine, PersistentEngineConfig, SimStages,
};
use amp4ec::server::EdgeServer;
use amp4ec::workload::Arrival;
use common::harness as h;
use common::harness::KillSwitchStages;

/// Shares for the engine-level chain: stage 1 is the bottleneck and the
/// one that gets replicated.
const SHARES: &[f64] = &[1.0, 0.25, 1.0];

fn replay_engine(
    stages: KillSwitchStages<SimStages>,
    depth: usize,
    replay: bool,
) -> PersistentEngine {
    PersistentEngine::new(
        Arc::new(stages),
        PersistentEngineConfig {
            micro_batch_rows: 1,
            initial_depth: depth,
            replay,
            ..Default::default()
        },
    )
    .unwrap()
}

fn golden(rows: usize, seed: u64) -> (amp4ec::runtime::Tensor, amp4ec::runtime::Tensor) {
    let t = h::seeded_input(rows, 4, seed);
    let g = run_serial(&SimStages::heterogeneous(SHARES, 1.0), &t, 1)
        .unwrap()
        .output;
    (t, g)
}

#[test]
fn replay_recovers_killed_replica_mid_stream() {
    // Replica 1 of stage 1 serves two micro-batches, then dies with
    // work in flight. The driver must replay the failed micro-batches
    // on the surviving replica: the batch completes, bit-identical to
    // the serial schedule, with no re-partition and no failed handle.
    let stages = KillSwitchStages::new(SimStages::with_replicas(
        SHARES,
        1.0,
        &[1, 2, 1],
    ));
    stages.kill_after(1, 1, 2);
    let engine = replay_engine(stages, 4, true);
    let (t, want) = golden(8, 0xC0FFEE);

    let run = engine.submit(&t).unwrap().wait().expect("replayed batch");
    assert_eq!(run.output, want, "replayed output diverged from serial");
    let replays = engine.replay_stats();
    assert!(
        replays.succeeded >= 1,
        "the kill schedule guarantees at least one replay: {replays:?}"
    );
    assert!(replays.attempted >= replays.succeeded);

    // The survivor keeps serving whole batches after the death.
    let again = engine.submit(&t).unwrap().wait().unwrap();
    assert_eq!(again.output, want, "post-death output diverged");
}

#[test]
fn replay_off_reproduces_fail_fast() {
    // The same kill schedule with healing off must fail the doomed
    // batch — today's behaviour, pinned so replay stays opt-in.
    let stages = KillSwitchStages::new(SimStages::with_replicas(
        SHARES,
        1.0,
        &[1, 2, 1],
    ));
    stages.kill_after(1, 1, 2);
    let engine = replay_engine(stages, 4, false);
    let (t, want) = golden(8, 0xC0FFEE);

    let err = match engine.submit(&t).unwrap().wait() {
        Ok(_) => panic!("fail-fast batch must surface the node death"),
        Err(e) => e,
    };
    assert!(
        format!("{err:#}").contains("died mid-stream"),
        "wrong failure surfaced: {err:#}"
    );
    assert_eq!(engine.replay_stats(), Default::default());

    // Fail-fast still steers *new* work around the dead replica.
    let again = engine.submit(&t).unwrap().wait().unwrap();
    assert_eq!(again.output, want);
}

#[test]
fn revived_replica_rejoins_routing() {
    // Warm re-admission at the engine layer: a killed replica that
    // comes back re-enters the alive set and takes micro-batches again.
    let stages = Arc::new(KillSwitchStages::new(SimStages::with_replicas(
        SHARES,
        1.0,
        &[1, 2, 1],
    )));
    let engine = PersistentEngine::new(
        Arc::clone(&stages),
        PersistentEngineConfig {
            micro_batch_rows: 1,
            initial_depth: 4,
            replay: true,
            ..Default::default()
        },
    )
    .unwrap();
    let (t, want) = golden(8, 0xBEEF);

    stages.kill(1, 1);
    let run = engine.submit(&t).unwrap().wait().unwrap();
    assert_eq!(run.output, want);
    let doomed_before = engine
        .replica_counters()
        .iter()
        .find(|c| c.stage == 1 && c.replica == 1)
        .map(|c| c.micro_batches)
        .unwrap_or(0);

    stages.revive(1, 1);
    let run = engine.submit(&t).unwrap().wait().unwrap();
    assert_eq!(run.output, want, "post-revival output diverged");
    let doomed_after = engine
        .replica_counters()
        .iter()
        .find(|c| c.stage == 1 && c.replica == 1)
        .map(|c| c.micro_batches)
        .unwrap_or(0);
    assert!(
        doomed_after > doomed_before,
        "revived lane took no work ({doomed_before} -> {doomed_after})"
    );
}

// ---------------------------------------------------------------------
// Server-level heal ladder (artifact-gated).
// ---------------------------------------------------------------------

fn heal_config() -> AmpConfig {
    let mut cfg = AmpConfig::paper_cluster(&common::artifacts_dir());
    cfg.monitor_interval_ms = 10;
    cfg.miss_threshold = 2;
    cfg.heal = true;
    cfg.model_cache = true; // heals re-ship from the node-local cache
    cfg
}

/// Poll `cond` until it holds or the deadline passes.
fn wait_for(what: &str, mut cond: impl FnMut() -> bool) {
    let deadline = Instant::now() + Duration::from_secs(60);
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(20));
    }
}

#[test]
fn heal_replaces_dead_replica_without_repartition() {
    require_artifacts!();
    // Four nodes, three partitions: the spare node hosts the hot
    // stage's extra replica. Killing it must heal by re-placement —
    // the partition plan (3 stages) survives untouched.
    let mut cfg = heal_config();
    cfg.nodes
        .push(amp4ec::config::NodeConfig::new("edge-spare", 1.0, 1024.0));
    cfg.num_partitions = Some(3); // 4th node stays spare -> hosts the replica
    cfg.replicas = amp4ec::config::ReplicaPolicy::parse("auto").unwrap();
    let server = Arc::new(EdgeServer::start(cfg).unwrap());
    let _watchdog = server.start_heal_watchdog(Duration::from_millis(10));
    assert_eq!(server.plan().partitions.len(), 3);

    // The replica-only victim: online but not hosting any primary.
    let primaries = server.service().deployment_nodes();
    let victim = server
        .cluster
        .online_nodes()
        .iter()
        .map(|n| n.id())
        .find(|id| !primaries.contains(id))
        .expect("one node hosts only the extra replica");
    server.cluster.remove_node(victim);

    wait_for("replica re-placement heal", || {
        server.churn_stats().heals_replaced >= 1
    });
    assert_eq!(
        server.plan().partitions.len(),
        3,
        "replica heal must not re-partition"
    );
    let report = server.serve_workload(8, 8, Arrival::Closed, 11).unwrap();
    assert_eq!(report.metrics.completed, 8);
    assert_eq!(report.metrics.failed, 0);
    assert!(report.churn.nodes_died >= 1);
    assert!(report.churn.heals_replaced >= 1);
    assert_eq!(report.churn.heals_repartitioned, 0);
}

#[test]
fn heal_repartitions_when_stage_loses_every_replica() {
    require_artifacts!();
    // Three nodes, three unreplicated stages: losing any node leaves
    // its stage with no surviving replica, so the ladder must fall back
    // to a full re-partition over the two survivors.
    let server = Arc::new(EdgeServer::start(heal_config()).unwrap());
    let _watchdog = server.start_heal_watchdog(Duration::from_millis(10));
    assert_eq!(server.plan().partitions.len(), 3);

    let victim = server.cluster.online_nodes().last().unwrap().id();
    server.cluster.remove_node(victim);

    wait_for("re-partition heal", || {
        server.churn_stats().heals_repartitioned >= 1
    });
    wait_for("2-node plan", || server.plan().partitions.len() == 2);
    let report = server.serve_workload(8, 8, Arrival::Closed, 12).unwrap();
    assert_eq!(report.metrics.completed, 8);
    assert_eq!(report.metrics.failed, 0);
    assert!(report.churn.nodes_died >= 1);
    assert!(report.churn.heals_repartitioned >= 1);
}

#[test]
fn returned_node_is_readmitted_and_counted() {
    require_artifacts!();
    let server = Arc::new(EdgeServer::start(heal_config()).unwrap());
    let _watchdog = server.start_heal_watchdog(Duration::from_millis(10));

    let victim = server.cluster.online_nodes().last().unwrap().id();
    server.cluster.remove_node(victim);
    wait_for("death observed", || server.churn_stats().nodes_died >= 1);

    // Warm return: the node resurfaces; the monitor notices and the
    // watchdog counts it back into the spare pool.
    server.cluster.readmit_node(victim);
    wait_for("return observed", || {
        server.churn_stats().nodes_returned >= 1
    });
    // The returned node is spare capacity again: a rebalance plans over
    // all three nodes.
    let sizes = server.rebalance().unwrap();
    assert_eq!(sizes.len(), 3, "returned node must be plannable: {sizes:?}");
    let report = server.serve_workload(4, 4, Arrival::Closed, 13).unwrap();
    assert_eq!(report.metrics.completed, 4);
}

#[test]
fn kill_during_rebalance_converges() {
    require_artifacts!();
    // Two deaths in quick succession: the second lands while the heal
    // of the first is (likely) still deploying. The ladder must keep
    // converging — the watchdog folds the monitor's full dead set into
    // every retry — and serving must resume on the final topology.
    let server = Arc::new(EdgeServer::start(heal_config()).unwrap());
    let _watchdog = server.start_heal_watchdog(Duration::from_millis(10));

    let victims: Vec<usize> = server
        .cluster
        .online_nodes()
        .iter()
        .skip(1)
        .map(|n| n.id())
        .collect();
    server.cluster.remove_node(victims[0]);
    server.cluster.remove_node(victims[1]);

    wait_for("1-node plan", || server.plan().partitions.len() == 1);
    let report = server.serve_workload(4, 4, Arrival::Closed, 14).unwrap();
    assert_eq!(report.metrics.completed, 4);
    assert_eq!(report.metrics.failed, 0);
    assert!(report.churn.nodes_died >= 2);
}

#[test]
fn auto_rebalance_sees_equal_count_membership_swap() {
    require_artifacts!();
    // Regression (ISSUE 8 satellite): the watchdog used to compare
    // online_count() snapshots, so a leave+join that nets out to the
    // same count — one node swapped for another — was invisible and the
    // deployment kept targeting the departed node forever. The
    // membership epoch bumps on both transitions, so the swap must now
    // trigger a rebalance onto the joined node.
    let mut cfg = heal_config();
    cfg.heal = false; // isolate the auto-rebalance path
    let server = Arc::new(EdgeServer::start(cfg).unwrap());
    let _watchdog =
        server.start_auto_rebalance(Duration::from_millis(20));

    let victim = server.cluster.online_nodes().last().unwrap().id();
    // Back-to-back swap, far faster than one watchdog interval: the
    // online count is 3 before and after.
    let joined = server
        .cluster
        .add_node(amp4ec::cluster::NodeSpec::new("edge-swap", 1.0, 1024.0));
    server.cluster.remove_node(victim);
    assert_eq!(server.cluster.online_count(), 3);

    wait_for("rebalance onto the joined node", || {
        server.service().deployment_nodes().contains(&joined)
    });
    let nodes = server.service().deployment_nodes();
    assert!(
        !nodes.contains(&victim),
        "departed node still hosts a stage: {nodes:?}"
    );
    let report = server.serve_workload(4, 4, Arrival::Closed, 15).unwrap();
    assert_eq!(report.metrics.completed, 4);
}

#[test]
fn serving_rides_through_mid_run_node_loss() {
    require_artifacts!();
    // The end-to-end acceptance shape: a node dies *while* a workload
    // streams. Every response handle must resolve (no hung requests);
    // with the heal ladder plus ingress retries the run finishes, and
    // anything that could not be saved is an accounted failure or shed,
    // never a hang.
    let server = Arc::new(EdgeServer::start(heal_config()).unwrap());
    let _watchdog = server.start_heal_watchdog(Duration::from_millis(10));
    let n = 24;

    let victim = server.cluster.online_nodes().last().unwrap().id();
    let killer = {
        let server = Arc::clone(&server);
        std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            server.cluster.remove_node(victim);
        })
    };
    let report = server.serve_workload(n, n, Arrival::Closed, 16).unwrap();
    killer.join().unwrap();

    // Zero hung handles: everything is accounted as completed, failed,
    // or shed (serve_workload only returns once every handle resolved —
    // the counts must reconcile).
    let m = &report.metrics;
    assert_eq!(
        m.completed + m.failed + m.total_shed(),
        n as u64,
        "requests unaccounted for"
    );
    // The heal landed: the run saw the death and kept serving.
    wait_for("heal after mid-run death", || {
        let s = server.churn_stats();
        s.heals_replaced + s.heals_repartitioned >= 1
    });
    let after = server.serve_workload(8, 8, Arrival::Closed, 17).unwrap();
    assert_eq!(after.metrics.completed, 8);
    assert_eq!(after.metrics.failed, 0);
}
