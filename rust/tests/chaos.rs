//! Chaos-hardening integration tests (ISSUE 10).
//!
//! A seeded byte-level fault proxy ([`ChaosProxy`]) sits between the
//! coordinator and one agent and injects fragmentation, delays,
//! corruption, and mid-frame disconnects. The contract under test:
//! under *benign* chaos (reordered chunk boundaries, jitter) the wire
//! chain stays bit-identical to the in-process chain; under *hostile*
//! chaos (corruption, stalls, severs) every batch handle resolves —
//! Ok bit-identical or Err, never a hang, never silently wrong bytes.
//! Alongside: the agent-side stalled-client regression, the
//! per-execute deadline, concurrent dead-replica redial, and engine
//! straggler hedging.

mod common;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use amp4ec::pipeline::engine::{
    run_serial, HedgeConfig, PersistentEngine, PersistentEngineConfig,
    SimStages, StageExec,
};
use amp4ec::runtime::Tensor;
use amp4ec::transport::agent::{AgentHandle, NodeAgent};
use amp4ec::transport::chaos::{ChaosProxy, ConnPlans, FaultPlan};
use amp4ec::transport::{AgentAddr, TransportKind, WireStages};

use common::harness as h;

const CONNECT_TIMEOUT: Duration = Duration::from_secs(10);

/// Bit-exact tensor comparison (no epsilon — chaos that only touches
/// delivery must not perturb a single bit).
fn assert_bits_eq(a: &Tensor, b: &Tensor, ctx: &str) {
    assert_eq!(a.shape, b.shape, "{ctx}: shape mismatch");
    for (i, (x, y)) in a.data().iter().zip(b.data().iter()).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{ctx}: element {i} differs: {x} vs {y}"
        );
    }
}

fn close_ms(a: f64, b: f64, what: &str) {
    assert!((a - b).abs() < 1e-9, "{what}: {a} vs {b}");
}

/// Spawn `n` UDS agents on unique temp-socket paths.
fn uds_agents(n: usize, tag: &str) -> (Vec<AgentHandle>, Vec<AgentAddr>) {
    let dir = std::env::temp_dir();
    let mut handles = Vec::with_capacity(n);
    let mut addrs = Vec::with_capacity(n);
    for i in 0..n {
        let path =
            dir.join(format!("amp4ec-{tag}-{}-{i}.sock", std::process::id()));
        let agent = NodeAgent::serve_uds(&path).unwrap();
        addrs.push(agent.addr().clone());
        handles.push(agent);
    }
    (handles, addrs)
}

fn proxy_sock(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir()
        .join(format!("amp4ec-{tag}-{}-proxy.sock", std::process::id()))
}

/// Regression: a client that connects, sends a partial frame, and then
/// goes silent forever must not pin an exit-on-idle agent. Before the
/// idle deadline existed, the agent's handler blocked in `read_exact`
/// on the half-frame and `active_connections` never fell back to zero,
/// so the accept loop span forever and a coordinator crash leaked the
/// agent process.
#[test]
fn stalled_client_cannot_pin_idle_agent() {
    let path = proxy_sock("chaos-stall-client");
    let agent = NodeAgent::serve_uds(&path).unwrap();
    agent.exit_when_idle(true);
    agent.set_idle_timeout(Duration::from_millis(300));

    // A raw client: half a frame header, then silence. Held open for
    // the whole test so only the idle deadline can free the handler.
    let client = std::os::unix::net::UnixStream::connect(&path).unwrap();
    use std::io::Write;
    (&client).write_all(&[0x2a, 0x00, 0x00]).unwrap();

    let (tx, rx) = mpsc::channel();
    std::thread::spawn(move || {
        agent.join();
        let _ = tx.send(());
    });
    rx.recv_timeout(Duration::from_secs(10)).expect(
        "agent did not exit: a stalled client pinned the idle handler",
    );
    drop(client);
}

/// A stalled-but-connected agent (every reply delayed ~1s by the
/// proxy) must trip the per-execute deadline: the micro-batch fails
/// within the budget, the replica is marked suspect, and the healthy
/// stages keep serving.
#[test]
fn execute_deadline_marks_stalled_replica_suspect() {
    let (_agents, addrs) = uds_agents(3, "chaos-deadline");
    let proxy = ChaosProxy::start_uds(
        proxy_sock("chaos-deadline"),
        addrs[1].clone(),
        vec![ConnPlans {
            to_upstream: FaultPlan::clean(0xD1),
            to_client: FaultPlan::clean(0xD2).with_delays(1.0, 900.0, 1100.0),
        }],
    )
    .unwrap();
    let wired = vec![addrs[0].clone(), proxy.addr().clone(), addrs[2].clone()];
    let wire =
        WireStages::connect_sim(&wired, h::PAPER_SHARES, 2.0, CONNECT_TIMEOUT)
            .unwrap()
            .with_execute_timeout(Some(Duration::from_millis(250)));

    let input = h::seeded_input(2, 3, 5);
    let reference = SimStages::heterogeneous(h::PAPER_SHARES, 2.0);

    // Healthy stage first: the deadline must not perturb fast paths.
    let (out0, ms0) = wire.execute_on(0, 0, input.clone()).unwrap();
    let (ref0, ref_ms0) = reference.execute(0, input.clone()).unwrap();
    assert_bits_eq(&out0, &ref0, "stage 0 under a deadline");
    assert_eq!(ms0.to_bits(), ref_ms0.to_bits());

    // The stalled stage: fails within the budget (plus slack), marked
    // suspect — not a hang, not a 1s wait per micro-batch forever.
    let t0 = Instant::now();
    let err = wire
        .execute_on(1, 0, input.clone())
        .expect_err("stalled replica must blow the execute deadline");
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "deadline took {:?} to fire",
        t0.elapsed()
    );
    assert!(
        format!("{err:#}").contains("suspect"),
        "wrong failure surfaced: {err:#}"
    );
    assert!(wire.any_dead(), "deadline breach must mark the replica dead");
    assert!(!wire.replica_alive(1, 0));

    // Unaffected stages still serve after the breach.
    let (out2, _) = wire.execute_on(2, 0, input.clone()).unwrap();
    let (ref2, _) = reference.execute(2, input).unwrap();
    assert_bits_eq(&out2, &ref2, "stage 2 after the breach");
    proxy.stop();
}

/// Benign chaos — adversarial fragmentation plus small random delays
/// in both directions on one stage's connection — must be invisible:
/// outputs and simulated timing bit-identical to the in-process chain,
/// zero hangs, no replica marked dead.
#[test]
fn fragmented_jittery_link_is_bit_transparent_uds() {
    let (_agents, addrs) = uds_agents(3, "chaos-benign");
    let proxy = ChaosProxy::start_uds(
        proxy_sock("chaos-benign"),
        addrs[1].clone(),
        vec![ConnPlans {
            to_upstream: FaultPlan::clean(0xB1)
                .with_fragmentation(9)
                .with_delays(0.2, 0.0, 2.0),
            to_client: FaultPlan::clean(0xB2)
                .with_fragmentation(9)
                .with_delays(0.2, 0.0, 2.0),
        }],
    )
    .unwrap();
    let wired = vec![addrs[0].clone(), proxy.addr().clone(), addrs[2].clone()];
    let wire = Arc::new(
        WireStages::connect_sim(&wired, h::PAPER_SHARES, 2.0, CONNECT_TIMEOUT)
            .unwrap(),
    );
    assert_eq!(wire.kind(), TransportKind::Uds);

    // Watchdog: the chaotic runs happen on a worker thread so a hang
    // surfaces as a recv timeout instead of a stuck test binary.
    let chaotic = Arc::clone(&wire);
    let (tx, rx) = mpsc::channel();
    std::thread::spawn(move || {
        let engine = h::engine(chaotic, 4);
        let runs: Vec<_> = (0..3u64)
            .map(|seed| {
                engine.run(&h::seeded_input(5, 3, 900 + seed)).unwrap()
            })
            .collect();
        let _ = tx.send(runs);
    });
    let runs = rx
        .recv_timeout(Duration::from_secs(60))
        .expect("benign chaos must not hang the wire engine");

    let local = h::engine(h::paper_stages(2.0), 4);
    for (seed, w) in runs.iter().enumerate() {
        let l = local.run(&h::seeded_input(5, 3, 900 + seed as u64)).unwrap();
        assert_bits_eq(&w.output, &l.output, "fragmented uds output");
        close_ms(w.timing.total_ms, l.timing.total_ms, "total_ms");
        close_ms(w.timing.compute_ms, l.timing.compute_ms, "compute_ms");
        close_ms(w.timing.comm_ms, l.timing.comm_ms, "comm_ms");
    }
    assert!(!wire.any_dead(), "benign chaos must not kill a replica");
    proxy.stop();
}

/// Same transparency contract over TCP (Nagle, kernel buffering, and
/// the proxy's re-chunking all in play).
#[test]
fn fragmented_jittery_link_is_bit_transparent_tcp() {
    let a0 = NodeAgent::serve_tcp("127.0.0.1:0").unwrap();
    let a1 = NodeAgent::serve_tcp("127.0.0.1:0").unwrap();
    let a2 = NodeAgent::serve_tcp("127.0.0.1:0").unwrap();
    let proxy = ChaosProxy::start_tcp(
        "127.0.0.1:0",
        a1.addr().clone(),
        vec![ConnPlans {
            to_upstream: FaultPlan::clean(0xC1).with_fragmentation(7),
            to_client: FaultPlan::clean(0xC2)
                .with_fragmentation(7)
                .with_delays(0.15, 0.0, 2.0),
        }],
    )
    .unwrap();
    let wired =
        vec![a0.addr().clone(), proxy.addr().clone(), a2.addr().clone()];
    let wire = Arc::new(
        WireStages::connect_sim(&wired, h::PAPER_SHARES, 2.0, CONNECT_TIMEOUT)
            .unwrap(),
    );
    assert_eq!(wire.kind(), TransportKind::Tcp);

    let chaotic = Arc::clone(&wire);
    let (tx, rx) = mpsc::channel();
    std::thread::spawn(move || {
        let engine = h::engine(chaotic, 4);
        let run = engine.run(&h::seeded_input(6, 2, 77)).unwrap();
        let _ = tx.send(run);
    });
    let w = rx
        .recv_timeout(Duration::from_secs(60))
        .expect("benign chaos must not hang the tcp wire engine");
    let l = h::engine(h::paper_stages(2.0), 4)
        .run(&h::seeded_input(6, 2, 77))
        .unwrap();
    assert_bits_eq(&w.output, &l.output, "fragmented tcp output");
    close_ms(w.timing.total_ms, l.timing.total_ms, "total_ms");
    assert!(!wire.any_dead());
    proxy.stop();
}

/// Hostile chaos: scheduled bit-flips on the coordinator->agent stream
/// well past the handshake. The CRC layer must turn corruption into a
/// connection error — every handle resolves (no hangs), whatever
/// completes is bit-identical, at least one batch fails, and the
/// poisoned replica is marked dead. Silently wrong output anywhere is
/// the one unacceptable outcome. The execute deadline backstops the
/// one corruption CRC cannot catch promptly: a flipped *length* byte
/// that leaves the agent waiting for a frame that never finishes.
#[test]
fn scheduled_corruption_fails_batches_never_corrupts_outputs() {
    let (_agents, addrs) = uds_agents(3, "chaos-corrupt");
    let proxy = ChaosProxy::start_uds(
        proxy_sock("chaos-corrupt"),
        addrs[1].clone(),
        vec![ConnPlans {
            to_upstream: FaultPlan::clean(0xE1)
                .with_corruption_at(vec![900, 1400]),
            to_client: FaultPlan::clean(0xE2),
        }],
    )
    .unwrap();
    let wired = vec![addrs[0].clone(), proxy.addr().clone(), addrs[2].clone()];
    let wire = Arc::new(
        WireStages::connect_sim(&wired, h::PAPER_SHARES, 2.0, CONNECT_TIMEOUT)
            .unwrap()
            .with_execute_timeout(Some(Duration::from_secs(2))),
    );

    let engine = h::engine(Arc::clone(&wire), 2);
    let inputs: Vec<Tensor> =
        (0..6u64).map(|seed| h::seeded_input(5, 3, 300 + seed)).collect();
    let handles: Vec<_> =
        inputs.iter().map(|t| engine.submit(t).unwrap()).collect();

    let (tx, rx) = mpsc::channel();
    std::thread::spawn(move || {
        let results: Vec<anyhow::Result<Tensor>> = handles
            .into_iter()
            .map(|handle| handle.wait().map(|run| run.output))
            .collect();
        let _ = tx.send(results);
    });
    let results = rx
        .recv_timeout(Duration::from_secs(30))
        .expect("batch handles hung after stream corruption");
    assert_eq!(results.len(), 6);
    assert!(
        results.iter().any(|r| r.is_err()),
        "scheduled corruption must fail at least one batch"
    );

    let local = h::engine(h::paper_stages(2.0), 2);
    for (i, r) in results.iter().enumerate() {
        if let Ok(out) = r {
            let golden = local.run(&inputs[i]).unwrap();
            assert_bits_eq(
                out,
                &golden.output,
                &format!("batch {i} completed across a corrupting link"),
            );
        }
    }
    assert!(wire.any_dead(), "the corrupted connection must be marked dead");
    proxy.stop();
}

/// `reconnect_dead` dials every dead replica concurrently: with two
/// unreachable agents and an 800 ms per-dial budget, the whole sweep
/// must finish in about one budget, not two (the serial sweep's lower
/// bound).
#[test]
fn reconnect_dead_dials_replicas_concurrently() {
    let (agents, addrs) = uds_agents(2, "chaos-redial");
    let mut wire =
        WireStages::connect_sim(&addrs, &[1.0, 0.6], 2.0, CONNECT_TIMEOUT)
            .unwrap()
            .with_execute_timeout(Some(Duration::from_secs(1)));

    // Kill and reap both agents (removes their socket files, so each
    // redial fails immediately and retries until its budget expires).
    for agent in &agents {
        agent.kill();
    }
    drop(agents);

    // Force both connections to notice: the reader threads see EOF and
    // mark the replicas dead; a nudge execute bounds the wait.
    let input = h::seeded_input(1, 3, 1);
    for stage in 0..2 {
        let deadline = Instant::now() + Duration::from_secs(10);
        while wire.replica_alive(stage, 0) {
            let _ = wire.execute_on(stage, 0, input.clone());
            assert!(
                Instant::now() < deadline,
                "stage {stage} never noticed its agent died"
            );
            std::thread::sleep(Duration::from_millis(20));
        }
    }

    let budget = Duration::from_millis(800);
    let t0 = Instant::now();
    let revived = wire.reconnect_dead(budget);
    let elapsed = t0.elapsed();
    assert_eq!(revived, 0, "agents are gone; nothing should revive");
    assert!(
        elapsed >= Duration::from_millis(700),
        "both dials should run their budget: {elapsed:?}"
    );
    assert!(
        elapsed < Duration::from_millis(1450),
        "dials ran serially: {elapsed:?} for two 800 ms budgets"
    );
    assert!(wire.any_dead());
}

/// Replica-aware straggler wrapper: once armed, every execution on one
/// lane stalls for `lag` of wall clock (the result is still correct —
/// a straggler, not a fault).
struct LaggyStages {
    inner: SimStages,
    lane: (usize, usize),
    lag: Duration,
    armed: Arc<AtomicBool>,
}

impl StageExec for LaggyStages {
    fn num_stages(&self) -> usize {
        self.inner.num_stages()
    }

    fn node_id(&self, stage: usize) -> usize {
        self.inner.node_id(stage)
    }

    fn comm_in(&self, stage: usize, bytes: u64) -> f64 {
        self.inner.comm_in(stage, bytes)
    }

    fn comm_out(&self, bytes: u64) -> f64 {
        self.inner.comm_out(bytes)
    }

    fn execute(&self, stage: usize, input: Tensor) -> anyhow::Result<(Tensor, f64)> {
        self.execute_on(stage, 0, input)
    }

    fn replicas(&self, stage: usize) -> usize {
        self.inner.replicas(stage)
    }

    fn replica_node_id(&self, stage: usize, replica: usize) -> usize {
        self.inner.replica_node_id(stage, replica)
    }

    fn comm_in_on(&self, stage: usize, replica: usize, bytes: u64) -> f64 {
        self.inner.comm_in_on(stage, replica, bytes)
    }

    fn execute_on(
        &self,
        stage: usize,
        replica: usize,
        input: Tensor,
    ) -> anyhow::Result<(Tensor, f64)> {
        if (stage, replica) == self.lane && self.armed.load(Ordering::SeqCst) {
            std::thread::sleep(self.lag);
        }
        self.inner.execute_on(stage, replica, input)
    }
}

/// Straggler hedging: after the per-stage latency estimate warms up,
/// one lane of the replicated stage turns into a straggler (correct
/// but slow). The engine must reissue its micro-batches to the healthy
/// sibling, count wins, and keep outputs bit-identical to the serial
/// reference — first-completion-wins is a pure scheduling change.
#[test]
fn hedging_reissues_straggler_micro_batches() {
    let shares = [1.0, 0.25, 1.0];
    let armed = Arc::new(AtomicBool::new(false));
    let stages = LaggyStages {
        inner: SimStages::with_replicas(&shares, 1.0, &[1, 2, 1]),
        lane: (1, 0),
        lag: Duration::from_millis(250),
        armed: Arc::clone(&armed),
    };
    let engine = PersistentEngine::new(
        Arc::new(stages),
        PersistentEngineConfig {
            micro_batch_rows: 1,
            initial_depth: 4,
            adaptive: None,
            // min_ms floors the threshold well above scheduler jitter
            // on a loaded CI box, while the 250 ms straggler still
            // overshoots it 5x.
            hedge: Some(HedgeConfig {
                factor: 3.0,
                min_ms: 50.0,
                min_samples: 2,
            }),
            ..Default::default()
        },
    )
    .unwrap();

    let input = h::seeded_input(6, 4, 0xAB);
    let golden = run_serial(&SimStages::heterogeneous(&shares, 1.0), &input, 1)
        .unwrap()
        .output;

    // Warm the estimator on the healthy chain.
    for _ in 0..2 {
        let run = engine.submit(&input).unwrap().wait().unwrap();
        assert_bits_eq(&run.output, &golden, "warmup batch");
    }
    assert_eq!(engine.hedge_stats().issued, 0, "no hedges on a healthy chain");

    // Arm the straggler and drive more batches through, with a
    // watchdog so a deadlocked hedge path cannot stick the test.
    armed.store(true, Ordering::SeqCst);
    let (tx, rx) = mpsc::channel();
    let handles: Vec<_> =
        (0..3).map(|_| engine.submit(&input).unwrap()).collect();
    std::thread::spawn(move || {
        let outs: Vec<anyhow::Result<Tensor>> = handles
            .into_iter()
            .map(|hdl| hdl.wait().map(|run| run.output))
            .collect();
        let _ = tx.send(outs);
    });
    let outs = rx
        .recv_timeout(Duration::from_secs(60))
        .expect("hedged batches hung");
    for (i, out) in outs.into_iter().enumerate() {
        let out = out.unwrap_or_else(|e| {
            panic!("hedged batch {i} failed: {e:#}")
        });
        assert_bits_eq(&out, &golden, &format!("hedged batch {i}"));
    }

    let stats = engine.hedge_stats();
    assert!(
        stats.issued >= 1,
        "straggler lane must trigger at least one hedge: {stats:?}"
    );
    assert!(
        stats.wins >= 1,
        "the healthy sibling should win at least once: {stats:?}"
    );
    assert_eq!(
        stats.issued,
        stats.wins + stats.wasted,
        "every hedge resolves as a win or a waste: {stats:?}"
    );
}
