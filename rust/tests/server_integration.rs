//! End-to-end integration: EdgeServer over the real artifacts — serving,
//! caching, adaptability (node join/leave), baseline comparison.

mod common;

use std::sync::Arc;

use amp4ec::baseline::{baseline_node_spec, MonolithicService};
use amp4ec::cluster::{Cluster, SimParams};
use amp4ec::config::AmpConfig;

use amp4ec::server::EdgeServer;
use amp4ec::serving::{IngressConfig, ServiceHandle};
use amp4ec::workload::{feed, Arrival, InputPool};

fn fast_config() -> AmpConfig {
    let mut cfg = AmpConfig::paper_cluster(&common::artifacts_dir());
    cfg.monitor_interval_ms = 20;
    cfg
}

#[test]
fn serve_small_workload_end_to_end() {
    require_artifacts!();
    let server = EdgeServer::start(fast_config()).unwrap();
    let report = server.serve_workload(8, 8, Arrival::Closed, 1).unwrap();
    assert_eq!(report.metrics.completed, 8);
    assert_eq!(report.metrics.failed, 0);
    assert!(report.metrics.throughput_rps() > 0.0);
    assert!(report.metrics.mean_latency_ms() > 0.0);
    assert_eq!(report.partition_layer_sizes, vec![108, 16, 17]);
    assert_eq!(report.node_names.len(), 3);
    assert!(report.deploy_transfer_bytes > 10_000_000); // ~14 MB of weights
    assert!(report.monitor_overhead_pct < 5.0);
}

#[test]
fn golden_parity_through_distributed_pipeline() {
    require_artifacts!();
    let server = EdgeServer::start(fast_config()).unwrap();
    let diff = server.golden_check().unwrap();
    assert!(diff < 1e-2, "diff {diff}");
}

#[test]
fn result_cache_short_circuits_repeats() {
    require_artifacts!();
    let mut cfg = fast_config();
    cfg.cache_entries = Some(64);
    let server = EdgeServer::start(cfg).unwrap();
    // Warm the cache with the 3 distinct inputs (cache persists on the
    // server across workloads), then every request in the measured run
    // must hit.
    let warm = server.serve_workload(3, 3, Arrival::Closed, 2).unwrap();
    assert_eq!(warm.metrics.completed, 3);
    let report = server.serve_workload(12, 3, Arrival::Closed, 2).unwrap();
    assert_eq!(report.metrics.completed, 12);
    assert_eq!(report.metrics.cache_hits, 12);
    let stats = report.cache_stats.unwrap();
    assert!(stats.hits >= 12);
    // Hits are far faster than the warm run's misses.
    assert!(report.metrics.mean_latency_ms()
        < warm.metrics.mean_latency_ms() / 2.0);
}

#[test]
fn model_cache_zeroes_redeploy_bandwidth() {
    require_artifacts!();
    let mut cfg = fast_config();
    cfg.model_cache = true;
    let server = EdgeServer::start(cfg).unwrap();
    // start() does a warm deploy then the real deploy: the measured one
    // must have moved zero bytes.
    let report = server.serve_workload(2, 2, Arrival::Closed, 3).unwrap();
    assert_eq!(report.deploy_transfer_bytes, 0);
    assert_eq!(report.metrics.completed, 2);
}

#[test]
fn node_offline_triggers_rebalance() {
    require_artifacts!();
    let server = EdgeServer::start(fast_config()).unwrap();
    assert_eq!(server.plan().partitions.len(), 3);
    // Take the last node offline (the paper's "device offline" scenario).
    let victims = server.cluster.online_nodes();
    server.cluster.remove_node(victims.last().unwrap().id());
    let sizes = server.rebalance().unwrap();
    assert_eq!(sizes, vec![116, 25]); // 2-node plan
    let report = server.serve_workload(4, 4, Arrival::Closed, 4).unwrap();
    assert_eq!(report.metrics.completed, 4);
    assert_eq!(report.metrics.failed, 0);
}

#[test]
fn node_join_triggers_scale_up() {
    require_artifacts!();
    let mut cfg = fast_config();
    cfg.nodes.truncate(2); // start with 2 nodes
    let server = EdgeServer::start(cfg).unwrap();
    assert_eq!(server.plan().partitions.len(), 2);
    // New device added (§I scenario 1).
    server
        .cluster
        .add_node(amp4ec::cluster::NodeSpec::new("edge-new", 1.0, 1024.0));
    let sizes = server.rebalance().unwrap();
    assert_eq!(sizes.len(), 3);
    let report = server.serve_workload(4, 4, Arrival::Closed, 5).unwrap();
    assert_eq!(report.metrics.completed, 4);
}

#[test]
fn auto_rebalance_watchdog_reacts_to_topology() {
    require_artifacts!();
    let mut cfg = fast_config();
    cfg.model_cache = true; // cheap redeploys
    let server = Arc::new(EdgeServer::start(cfg).unwrap());
    let _watchdog = server
        .start_auto_rebalance(std::time::Duration::from_millis(50));
    assert_eq!(server.plan().partitions.len(), 3);
    let victim = server.cluster.online_nodes().last().unwrap().id();
    server.cluster.remove_node(victim);
    // Wait for the watchdog to notice and redeploy.
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(60);
    loop {
        if server.plan().partitions.len() == 2 {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "watchdog did not rebalance in time"
        );
        std::thread::sleep(std::time::Duration::from_millis(50));
    }
    assert_eq!(server.plan().layer_sizes(), vec![116, 25]);
    // Service continues on the new deployment.
    let report = server.serve_workload(4, 4, Arrival::Closed, 6).unwrap();
    assert_eq!(report.metrics.completed, 4);
    // Energy accounting is live.
    assert!(!report.node_energy.is_empty());
    assert!(report.node_energy.iter().all(|(_, total, _)| *total > 0.0));
}

#[test]
fn monolithic_baseline_serves() {
    require_artifacts!();
    let manifest =
        amp4ec::manifest::Manifest::load(&common::artifacts_dir()).unwrap();
    let cluster = Cluster::new(SimParams::default());
    let id = cluster.add_node(baseline_node_spec());
    let node = cluster.get(id).unwrap();
    let svc = Arc::new(MonolithicService::new(&manifest, node, 1).unwrap());

    let pool = InputPool::new(svc.input_shape(), 4, 7);
    let handle = ServiceHandle::new(svc, IngressConfig::default(), None);
    feed(&handle, &pool, 4, Arrival::Closed, 8);
    let metrics = handle.finish();
    assert_eq!(metrics.completed, 4);
    assert_eq!(metrics.failed, 0);
    assert!(metrics.mean_latency_ms() > 0.0);
}

#[test]
fn distributed_tracks_monolithic_and_cache_beats_it() {
    require_artifacts!();
    // Table I shape, at miniature scale, under an *optimized* baseline:
    // plain AMP4EC must stay within 2.5x of the monolithic throughput
    // (equal aggregate compute, pipeline overheads), and AMP4EC+Cache
    // must strictly beat the monolithic on throughput. (The paper's 5x
    // gap for cache-less AMP4EC is an artifact of its unoptimized
    // baseline — 0.96 req/s for MobileNetV2; see EXPERIMENTS.md.)
    let n_req = 24;

    // Monolithic.
    let manifest =
        amp4ec::manifest::Manifest::load(&common::artifacts_dir()).unwrap();
    let cluster = Cluster::new(SimParams::default());
    let id = cluster.add_node(baseline_node_spec());
    let svc = Arc::new(
        MonolithicService::new(&manifest, cluster.get(id).unwrap(), 1).unwrap(),
    );
    let pool = InputPool::new(svc.input_shape(), n_req, 9);
    let handle = ServiceHandle::new(svc, IngressConfig::default(), None);
    feed(&handle, &pool, n_req, Arrival::Closed, 10);
    let mono = handle.finish();

    // Distributed: batch-8 artifacts + profile-guided partitions.
    let mut cfg = fast_config();
    cfg.batch = 8;
    cfg.profiled_partitioning = true;
    cfg.cache_entries = Some(128);
    let server = EdgeServer::start(cfg).unwrap();
    let dist = server
        .serve_workload(n_req, n_req, Arrival::Closed, 9)
        .unwrap()
        .metrics;
    assert!(
        dist.throughput_rps() > mono.throughput_rps() / 2.5,
        "distributed {:.2} rps vs monolithic {:.2} rps",
        dist.throughput_rps(),
        mono.throughput_rps()
    );

    // Warm cache: repeated inputs now short-circuit the pipeline.
    let cached = server
        .serve_workload(n_req, n_req, Arrival::Closed, 9)
        .unwrap()
        .metrics;
    assert!(
        cached.throughput_rps() > mono.throughput_rps(),
        "cached {:.2} rps must beat monolithic {:.2} rps",
        cached.throughput_rps(),
        mono.throughput_rps()
    );
}
