//! Partitioner over the real MobileNetV2 manifest: the paper's §IV-D
//! numbers must reproduce exactly, plus invariants at every partition
//! count the block grid supports.

mod common;

use amp4ec::manifest::Manifest;
use amp4ec::partitioner::{self, cost};

#[test]
fn paper_partition_sizes_exact() {
    require_artifacts!();
    let m = Manifest::load(&common::artifacts_dir()).unwrap();
    assert_eq!(partitioner::plan(&m, 2).unwrap().layer_sizes(), vec![116, 25]);
    assert_eq!(
        partitioner::plan(&m, 3).unwrap().layer_sizes(),
        vec![108, 16, 17]
    );
}

#[test]
fn manifest_matches_torchvision_shape() {
    require_artifacts!();
    let m = Manifest::load(&common::artifacts_dir()).unwrap();
    let layers = m.flat_layers();
    assert_eq!(layers.len(), 141);
    assert_eq!(m.blocks.len(), 20);
    let convs = layers
        .iter()
        .filter(|l| l.kind == amp4ec::manifest::LayerKind::Conv2d)
        .count();
    assert_eq!(convs, 52);
}

#[test]
fn all_partition_counts_valid() {
    require_artifacts!();
    let m = Manifest::load(&common::artifacts_dir()).unwrap();
    for n in 1..=m.blocks.len() {
        let p = partitioner::plan(&m, n).unwrap();
        assert_eq!(p.partitions.len(), n, "n={n}");
        assert_eq!(p.layer_sizes().iter().sum::<usize>(), 141, "n={n}");
        assert!(p.partitions.iter().all(|x| !x.block_range.is_empty()));
        // Contiguous block tiling.
        assert_eq!(p.partitions[0].block_range.start, 0);
        assert_eq!(p.partitions.last().unwrap().block_range.end, m.blocks.len());
        // Communication estimates positive and bounded by largest
        // activation.
        for c in p.comm_bytes(&m, 1) {
            assert!(c > 0);
            assert!(c <= 8 * 48 * 48 * 96 * 4);
        }
    }
}

#[test]
fn weighted_plan_tracks_cpu_shares() {
    require_artifacts!();
    let m = Manifest::load(&common::artifacts_dir()).unwrap();
    let p = partitioner::plan_weighted(&m, &[1.0, 0.6, 0.4]).unwrap();
    let costs: Vec<u64> = p.partitions.iter().map(|x| x.cost).collect();
    let total: u64 = costs.iter().sum();
    // First (heaviest-weighted) partition carries the largest share and
    // roughly half the cost.
    let share0 = costs[0] as f64 / total as f64;
    assert!(share0 > 0.40 && share0 < 0.65, "share0 {share0}");
    assert!(costs[0] >= costs[2]);
}

#[test]
fn ablation_flops_cost_shifts_boundary() {
    require_artifacts!();
    let m = Manifest::load(&common::artifacts_dir()).unwrap();
    let paper = partitioner::plan(&m, 2).unwrap().layer_sizes();
    let flops = partitioner::layer_sizes_flops_cost(&m, 2);
    assert_eq!(flops.iter().sum::<usize>(), 141);
    // Correcting the depthwise overcount moves the cut point.
    assert_ne!(paper, flops);
}

#[test]
fn range_cost_prefix_sums_stay_pinned() {
    // Artifact-free property sweep (ISSUE 3 equivalence satellite): on
    // seeded random cost arrays, every half-open range's O(1)
    // prefix-sum cost must equal the naive rescan, and the plan-level
    // invariant Σ range_cost(partitions) == Σ costs must hold.
    use amp4ec::util::rng::Rng;
    let mut rng = Rng::new(0xC057);
    for trial in 0..20 {
        let len = rng.range(1, 40);
        let costs: Vec<u64> =
            (0..len).map(|_| rng.below(1_000) as u64).collect();
        let prefix = partitioner::prefix_sums(&costs);
        assert_eq!(prefix.len(), len + 1);
        assert_eq!(prefix[0], 0);
        for a in 0..=len {
            for b in a..=len {
                let naive: u64 = costs[a..b].iter().sum();
                assert_eq!(
                    partitioner::range_cost(&prefix, &(a..b)),
                    naive,
                    "trial {trial}, range {a}..{b}"
                );
            }
        }
        let parts = rng.range(1, len);
        let ranges = partitioner::layer_boundaries_with(&costs, parts);
        let total: u64 = ranges
            .iter()
            .map(|r| partitioner::range_cost(&prefix, r))
            .sum();
        assert_eq!(total, costs.iter().sum::<u64>(), "trial {trial}");
    }
}

#[test]
fn conv_cost_dominates_mobilenet() {
    require_artifacts!();
    let m = Manifest::load(&common::artifacts_dir()).unwrap();
    let layers = m.flat_layers();
    let conv: u64 = layers
        .iter()
        .filter(|l| l.kind == amp4ec::manifest::LayerKind::Conv2d)
        .map(|l| cost::layer_cost(l))
        .sum();
    let total: u64 = layers.iter().map(|l| cost::layer_cost(l)).sum();
    assert!(conv as f64 / total as f64 > 0.9);
}
