//! Loopback integration tests for the pluggable transport layer.
//!
//! The contract under test: a stage chain driven over the wire
//! (`WireStages` talking to `NodeAgent`s on UDS or TCP) is
//! *bit-identical* to the in-process chain — same outputs, same
//! simulated timing — for streaming, coalesced, and mixed-priority
//! serve runs; and a dropped agent fails in-flight work instead of
//! hanging it.

mod common;

use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use amp4ec::pipeline::engine::{
    PersistentEngine, SimStages, StageExec,
};
use amp4ec::runtime::Tensor;
use amp4ec::serving::{
    EngineService, IngressConfig, Outcome, Priority, ServiceHandle,
};
use amp4ec::transport::agent::{AgentHandle, NodeAgent};
use amp4ec::transport::{
    AgentAddr, InprocTransport, Transport, TransportKind, WireStages,
};

use common::harness as h;

const CONNECT_TIMEOUT: Duration = Duration::from_secs(10);

/// Bit-exact tensor comparison: shapes equal, every element's f32 bit
/// pattern equal (no epsilon — the wire must not perturb a single bit).
fn assert_bits_eq(a: &Tensor, b: &Tensor, ctx: &str) {
    assert_eq!(a.shape, b.shape, "{ctx}: shape mismatch");
    for (i, (x, y)) in a.data().iter().zip(b.data().iter()).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{ctx}: element {i} differs: {x} vs {y}"
        );
    }
}

fn close_ms(a: f64, b: f64, what: &str) {
    assert!((a - b).abs() < 1e-9, "{what}: {a} vs {b}");
}

/// Spawn `n` UDS agents on unique temp-socket paths.
fn uds_agents(n: usize, tag: &str) -> (Vec<AgentHandle>, Vec<AgentAddr>) {
    let dir = std::env::temp_dir();
    let mut handles = Vec::with_capacity(n);
    let mut addrs = Vec::with_capacity(n);
    for i in 0..n {
        let path =
            dir.join(format!("amp4ec-{tag}-{}-{i}.sock", std::process::id()));
        let agent = NodeAgent::serve_uds(&path).unwrap();
        addrs.push(agent.addr().clone());
        handles.push(agent);
    }
    (handles, addrs)
}

#[test]
fn inproc_transport_is_pure_delegation() {
    let t = InprocTransport::new(SimStages::heterogeneous(h::PAPER_SHARES, 2.0));
    let reference = SimStages::heterogeneous(h::PAPER_SHARES, 2.0);
    assert_eq!(t.kind(), TransportKind::Inproc);
    assert_eq!(t.endpoint(0), "inproc");
    assert_eq!(t.num_stages(), reference.num_stages());
    let input = h::seeded_input(2, 4, 11);
    for stage in 0..t.num_stages() {
        assert_eq!(t.node_id(stage), reference.node_id(stage));
        assert_eq!(t.backlog(stage), 0);
        let (a, a_ms) = t.execute(stage, input.clone()).unwrap();
        let (b, b_ms) = reference.execute(stage, input.clone()).unwrap();
        assert_bits_eq(&a, &b, "inproc delegation output");
        assert_eq!(a_ms.to_bits(), b_ms.to_bits(), "stage {stage} sim ms");
        assert_eq!(
            t.comm_in(stage, 4096).to_bits(),
            reference.comm_in(stage, 4096).to_bits()
        );
    }
    assert_eq!(t.comm_out(4096).to_bits(), reference.comm_out(4096).to_bits());
}

#[test]
fn uds_loopback_matches_inproc_streaming() {
    let (_agents, addrs) = uds_agents(3, "wt-uds");
    let wire = Arc::new(
        WireStages::connect_sim(&addrs, h::PAPER_SHARES, 2.0, CONNECT_TIMEOUT)
            .unwrap(),
    );
    assert_eq!(wire.kind(), TransportKind::Uds);
    for stage in 0..3 {
        assert_eq!(wire.endpoint(stage), addrs[stage].to_string());
    }
    let wire_engine = h::engine(Arc::clone(&wire), 4);
    let local_engine = h::engine(h::paper_stages(2.0), 4);
    for seed in 0..4u64 {
        let input = h::seeded_input(5, 3, 100 + seed);
        let w = wire_engine.run(&input).unwrap();
        let l = local_engine.run(&input).unwrap();
        assert_bits_eq(&w.output, &l.output, "uds streamed output");
        close_ms(w.timing.total_ms, l.timing.total_ms, "total_ms");
        close_ms(w.timing.compute_ms, l.timing.compute_ms, "compute_ms");
        close_ms(w.timing.comm_ms, l.timing.comm_ms, "comm_ms");
        assert_eq!(w.timing.activation_bytes, l.timing.activation_bytes);
    }
    assert!(!wire.any_dead());
}

#[test]
fn tcp_loopback_round_robins_stages_over_agents() {
    // 3 stages over 2 agents: stage 2 wraps back onto the first agent,
    // which therefore hosts two stage connections concurrently.
    let a0 = NodeAgent::serve_tcp("127.0.0.1:0").unwrap();
    let a1 = NodeAgent::serve_tcp("127.0.0.1:0").unwrap();
    let addrs = vec![a0.addr().clone(), a1.addr().clone()];
    let wire = Arc::new(
        WireStages::connect_sim(&addrs, h::PAPER_SHARES, 2.0, CONNECT_TIMEOUT)
            .unwrap(),
    );
    assert_eq!(wire.kind(), TransportKind::Tcp);
    assert_eq!(wire.endpoint(0), wire.endpoint(2));
    assert_ne!(wire.endpoint(0), wire.endpoint(1));
    assert_eq!(a0.active_connections(), 2);
    assert_eq!(a1.active_connections(), 1);

    let wire_engine = h::engine(Arc::clone(&wire), 4);
    let local_engine = h::engine(h::paper_stages(2.0), 4);
    let input = h::seeded_input(6, 2, 7);
    let w = wire_engine.run(&input).unwrap();
    let l = local_engine.run(&input).unwrap();
    assert_bits_eq(&w.output, &l.output, "tcp streamed output");
    close_ms(w.timing.total_ms, l.timing.total_ms, "total_ms");
}

#[test]
fn serve_runs_match_inproc_over_uds() {
    // Coalesced, mixed-priority serve traffic through the full ingress
    // (queue -> dispatcher -> engine) over the wire must produce the
    // same per-request outputs as the in-process reference.
    let (_agents, addrs) = uds_agents(3, "wt-serve");
    let wire = Arc::new(
        WireStages::connect_sim(&addrs, h::PAPER_SHARES, 2.0, CONNECT_TIMEOUT)
            .unwrap(),
    );
    let wire_engine = Arc::new(h::engine(wire, 4));
    let local_engine = h::engine(h::paper_stages(2.0), 4);

    let inputs: Vec<Tensor> =
        (0..9).map(|i| h::seeded_input(1, 4, 500 + i)).collect();
    let expected: Vec<Tensor> = inputs
        .iter()
        .map(|i| local_engine.run(i).unwrap().output)
        .collect();

    let cfg = IngressConfig {
        // Short admission window so requests coalesce into batches.
        max_wait: Duration::from_millis(5),
        ..Default::default()
    };
    let svc = ServiceHandle::new(
        Arc::new(EngineService::new(Arc::clone(&wire_engine), 1, 4)),
        cfg,
        None,
    );
    let prios = [Priority::HIGH, Priority::NORMAL, Priority::BEST_EFFORT];
    let handles: Vec<_> = inputs
        .iter()
        .enumerate()
        .map(|(i, input)| {
            svc.request(input.clone())
                .priority(prios[i % prios.len()])
                .tag(&format!("req-{i}"))
                .submit()
                .unwrap()
        })
        .collect();
    for (i, handle) in handles.into_iter().enumerate() {
        match handle.wait_timeout(Duration::from_secs(60)) {
            Some(Outcome::Done(resp)) => {
                assert_bits_eq(
                    &resp.output,
                    &expected[i],
                    &format!("serve request {i}"),
                );
            }
            Some(Outcome::Shed(reason)) => {
                panic!("request {i} shed ({reason:?}) with no deadline set")
            }
            Some(Outcome::Failed(e)) => panic!("request {i} failed: {e:#}"),
            None => panic!("request {i} still unresolved after 60s"),
        }
    }
    let metrics = svc.finish();
    assert_eq!(metrics.completed, inputs.len() as u64);
}

#[test]
fn agent_kill_mid_stream_fails_handles_without_hanging() {
    let (agents, addrs) = uds_agents(3, "wt-kill");
    let wire = Arc::new(
        WireStages::connect_sim(&addrs, h::PAPER_SHARES, 3.0, CONNECT_TIMEOUT)
            .unwrap(),
    );
    let engine = h::engine(Arc::clone(&wire), 2);

    // Queue a stream of batches, then sever the middle stage's agent
    // while they are in flight.
    let mut handles = Vec::new();
    for seed in 0..6u64 {
        handles.push(engine.submit(&h::seeded_input(4, 3, seed)).unwrap());
    }
    agents[1].kill();

    // Every handle must resolve — completed batches as Ok, batches cut
    // mid-stream as Err — within a hard bound: a watchdog thread drains
    // the waits so a hang shows up as a recv timeout, not a stuck test.
    let (tx, rx) = mpsc::channel();
    std::thread::spawn(move || {
        let results: Vec<anyhow::Result<Tensor>> = handles
            .into_iter()
            .map(|handle| handle.wait().map(|run| run.output))
            .collect();
        let _ = tx.send(results);
    });
    let results = rx
        .recv_timeout(Duration::from_secs(30))
        .expect("batch handles hung after agent kill");
    assert_eq!(results.len(), 6);
    assert!(
        results.iter().any(|r| r.is_err()),
        "killing an agent mid-stream must fail at least one in-flight batch"
    );

    // The severed stage is marked dead: later submissions fail fast
    // instead of writing into a broken pipe.
    assert!(wire.any_dead());
    let t0 = Instant::now();
    assert!(engine.run(&h::seeded_input(2, 3, 99)).is_err());
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "dead-stage submission should fail fast"
    );
}

#[test]
fn two_process_node_agents_match_inproc() {
    // The real thing: `amp4ec node` agents in child processes, dialed
    // over UDS. Outputs must be bit-identical to in-process, and the
    // agents (exit-on-idle by default) must exit 0 once the coordinator
    // disconnects.
    let bin = env!("CARGO_BIN_EXE_amp4ec");
    let dir = std::env::temp_dir();
    let mut children = Vec::new();
    let mut addrs = Vec::new();
    for i in 0..2 {
        let sock =
            dir.join(format!("amp4ec-2proc-{}-{i}.sock", std::process::id()));
        let _ = std::fs::remove_file(&sock);
        let child = std::process::Command::new(bin)
            .arg("node")
            .arg("--listen")
            .arg(&sock)
            .stdout(std::process::Stdio::null())
            .stderr(std::process::Stdio::null())
            .spawn()
            .expect("spawn amp4ec node");
        children.push(child);
        addrs.push(AgentAddr::Uds(sock));
    }

    // Run the comparison in a closure so children are reaped on every
    // path (a panic here would leave orphan processes behind).
    let body = || -> anyhow::Result<()> {
        let wire = Arc::new(WireStages::connect_sim(
            &addrs,
            h::PAPER_SHARES,
            2.0,
            Duration::from_secs(20),
        )?);
        let wire_engine = PersistentEngine::new(wire, h::engine_cfg(4))?;
        let local_engine =
            PersistentEngine::new(h::paper_stages(2.0), h::engine_cfg(4))?;
        for seed in 0..2u64 {
            let input = h::seeded_input(6, 3, 42 + seed);
            let w = wire_engine.run(&input)?;
            let l = local_engine.run(&input)?;
            anyhow::ensure!(
                w.output.shape == l.output.shape,
                "shape mismatch: {:?} vs {:?}",
                w.output.shape,
                l.output.shape
            );
            for (i, (x, y)) in
                w.output.data().iter().zip(l.output.data().iter()).enumerate()
            {
                anyhow::ensure!(
                    x.to_bits() == y.to_bits(),
                    "element {i} differs: {x} vs {y}"
                );
            }
        }
        Ok(())
    };
    let outcome = body();

    // The coordinator (WireStages) is gone; exit-on-idle agents must
    // notice and exit cleanly on their own.
    for (i, child) in children.iter_mut().enumerate() {
        let deadline = Instant::now() + Duration::from_secs(15);
        loop {
            match child.try_wait().expect("try_wait node child") {
                Some(status) => {
                    if outcome.is_ok() {
                        assert!(
                            status.success(),
                            "node agent {i} exited with {status}"
                        );
                    }
                    break;
                }
                None if Instant::now() >= deadline => {
                    let _ = child.kill();
                    let _ = child.wait();
                    if outcome.is_ok() {
                        panic!(
                            "node agent {i} did not exit after the \
                             coordinator disconnected"
                        );
                    }
                    break;
                }
                None => std::thread::sleep(Duration::from_millis(50)),
            }
        }
    }
    outcome.unwrap();
}
