//! Replica scale-out integration tests (ISSUE 7).
//!
//! The engine sprays micro-batches of a replicated stage across its
//! replicas and the sequence-numbered collector reassembles rows in
//! request order — so replication must be a pure scheduling change.
//! These tests attack exactly that boundary: a property test delays one
//! replica lane by an adversarial wall-clock backlog (its deliveries
//! arrive arbitrarily late and out of order) and requires bit-identical
//! reassembly, and a fault test kills one replica mid-run and requires
//! that only the batches with work in flight to it fail while the
//! surviving replicas keep serving.

mod common;

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use amp4ec::pipeline::engine::{
    run_serial, PersistentEngine, PersistentEngineConfig, SimStages,
    StageExec,
};
use amp4ec::runtime::Tensor;
use amp4ec::util::check::forall;
use common::harness as h;

/// Replica-aware fault wrapper (the harness [`common::harness::FaultStages`]
/// predates replication and deliberately erases the replica surface, so
/// it cannot target one lane). Forwards the full [`StageExec`] replica
/// API to the inner chain and injects, per `(stage, replica)` lane:
///
/// * an adversarial wall-clock delay — a backlog that reorders that
///   lane's deliveries against its siblings without touching sim time;
/// * a kill switch — after `kill_after` executions the lane errors
///   forever and reports itself dead, so the alive-set router steers new
///   work around it and only in-flight work fails.
struct ReplicaFaults {
    inner: SimStages,
    delay: Option<(usize, usize, Duration)>,
    doomed: Option<(usize, usize)>,
    kill_after: usize,
    doomed_execs: AtomicUsize,
    killed: AtomicBool,
}

impl ReplicaFaults {
    fn new(inner: SimStages) -> ReplicaFaults {
        ReplicaFaults {
            inner,
            delay: None,
            doomed: None,
            kill_after: 0,
            doomed_execs: AtomicUsize::new(0),
            killed: AtomicBool::new(false),
        }
    }

    /// Sleep `backlog` of wall clock before every execution on the lane.
    fn delay_on(mut self, stage: usize, replica: usize, backlog: Duration) -> Self {
        self.delay = Some((stage, replica, backlog));
        self
    }

    /// Kill the lane after `kill_after` successful executions on it.
    fn kill_on(mut self, stage: usize, replica: usize, kill_after: usize) -> Self {
        self.doomed = Some((stage, replica));
        self.kill_after = kill_after;
        self
    }
}

impl StageExec for ReplicaFaults {
    fn num_stages(&self) -> usize {
        self.inner.num_stages()
    }

    fn node_id(&self, stage: usize) -> usize {
        self.inner.node_id(stage)
    }

    fn comm_in(&self, stage: usize, bytes: u64) -> f64 {
        self.inner.comm_in(stage, bytes)
    }

    fn comm_out(&self, bytes: u64) -> f64 {
        self.inner.comm_out(bytes)
    }

    fn execute(&self, stage: usize, input: Tensor) -> anyhow::Result<(Tensor, f64)> {
        self.execute_on(stage, 0, input)
    }

    fn replicas(&self, stage: usize) -> usize {
        self.inner.replicas(stage)
    }

    fn replica_node_id(&self, stage: usize, replica: usize) -> usize {
        self.inner.replica_node_id(stage, replica)
    }

    fn replica_alive(&self, stage: usize, replica: usize) -> bool {
        if self.doomed == Some((stage, replica)) {
            !self.killed.load(Ordering::SeqCst)
        } else {
            self.inner.replica_alive(stage, replica)
        }
    }

    fn comm_in_on(&self, stage: usize, replica: usize, bytes: u64) -> f64 {
        self.inner.comm_in_on(stage, replica, bytes)
    }

    fn execute_on(
        &self,
        stage: usize,
        replica: usize,
        input: Tensor,
    ) -> anyhow::Result<(Tensor, f64)> {
        if let Some((s, r, backlog)) = self.delay {
            if (s, r) == (stage, replica) {
                std::thread::sleep(backlog);
            }
        }
        if self.doomed == Some((stage, replica)) {
            let n = self.doomed_execs.fetch_add(1, Ordering::SeqCst);
            if n >= self.kill_after {
                self.killed.store(true, Ordering::SeqCst);
                anyhow::bail!(
                    "injected replica death: stage {stage} replica {replica}"
                );
            }
        }
        self.inner.execute_on(stage, replica, input)
    }
}

fn engine_over(
    stages: ReplicaFaults,
    depth: usize,
) -> PersistentEngine {
    PersistentEngine::new(
        Arc::new(stages),
        PersistentEngineConfig {
            micro_batch_rows: 1,
            initial_depth: depth,
            adaptive: None,
            ..Default::default()
        },
    )
    .unwrap()
}

#[test]
fn property_reassembly_bit_identical_under_adversarial_replica_delays() {
    // One replica lane of the bottleneck stage runs with a random
    // wall-clock backlog, so its micro-batches overtake / fall behind
    // their siblings in real delivery order. The collector reassembles
    // by sequence number, so every case must reproduce the serial
    // output bit-for-bit — any row swap, loss, or duplication fails.
    forall(6, 0x5CA1E0, |rng| {
        let rows = rng.range(5, 13);
        let reps = rng.range(2, 3); // 2 or 3 replicas of the bottleneck
        let lagging = rng.below(reps);
        let backlog = Duration::from_millis(rng.range(1, 5) as u64);
        let shares = [1.0, 0.25, 1.0];
        let t = h::seeded_input(rows, 4, rng.next_u64());

        let golden = run_serial(&SimStages::heterogeneous(&shares, 1.0), &t, 1)
            .unwrap()
            .output;

        let stages = ReplicaFaults::new(SimStages::with_replicas(
            &shares,
            1.0,
            &[1, reps, 1],
        ))
        .delay_on(1, lagging, backlog);
        let engine = engine_over(stages, 4);
        // Two interleaved batches so late lane-`lagging` deliveries of
        // the first can land amid the second's.
        let a = engine.submit(&t).unwrap();
        let b = engine.submit(&t).unwrap();
        let out_a = a.wait().unwrap();
        let out_b = b.wait().unwrap();
        assert_eq!(out_a.output, golden, "batch A reassembly diverged");
        assert_eq!(out_b.output, golden, "batch B reassembly diverged");

        // Conservation: exactly `rows` micro-batches per batch crossed
        // the replicated stage, spread over its lanes.
        let crossed: u64 = engine
            .replica_counters()
            .iter()
            .filter(|c| c.stage == 1)
            .map(|c| c.micro_batches)
            .sum();
        assert_eq!(crossed, 2 * rows as u64, "lost or duplicated micro-batches");
    });
}

#[test]
fn replica_death_fails_only_in_flight_batches() {
    // Stage 1 runs two replicas; replica 1 dies on its first execution.
    // The batch with work in flight to it must fail (with the injected
    // error surfaced), a concurrently submitted single-row batch that
    // routes to replica 0 must complete, and after the death the
    // surviving replica must keep serving whole batches bit-identically.
    let shares = [1.0, 0.25, 1.0];
    let stages = ReplicaFaults::new(SimStages::with_replicas(
        &shares,
        1.0,
        &[1, 2, 1],
    ))
    .kill_on(1, 1, 0);
    let engine = engine_over(stages, 4);

    let doomed_input = h::seeded_input(4, 4, 7);
    let single_row = h::seeded_input(1, 4, 8);
    let golden_doomed =
        run_serial(&SimStages::heterogeneous(&shares, 1.0), &doomed_input, 1)
            .unwrap()
            .output;
    let golden_single =
        run_serial(&SimStages::heterogeneous(&shares, 1.0), &single_row, 1)
            .unwrap()
            .output;

    // Batch A routes its odd micro-batches to the doomed replica; batch
    // B's only micro-batch (sequence 0) routes to replica 0 whether or
    // not the death has been noticed yet.
    let a = engine.submit(&doomed_input).unwrap();
    let b = engine.submit(&single_row).unwrap();
    let err = match a.wait() {
        Ok(_) => panic!("batch on the dead replica must fail"),
        Err(e) => e,
    };
    assert!(
        format!("{err:#}").contains("injected replica death"),
        "wrong failure surfaced: {err:#}"
    );
    assert_eq!(
        b.wait().unwrap().output,
        golden_single,
        "concurrent batch on the surviving replica diverged"
    );

    // k-1 replicas keep serving: the alive-set router steers everything
    // to replica 0 now, so the same input that just failed completes.
    for _ in 0..2 {
        let run = engine.submit(&doomed_input).unwrap().wait().unwrap();
        assert_eq!(run.output, golden_doomed, "post-death output diverged");
    }
    let counters = engine.replica_counters();
    let survivor = counters
        .iter()
        .find(|c| c.stage == 1 && c.replica == 0)
        .expect("stage-1 primary counter");
    assert!(
        survivor.micro_batches >= 9,
        "survivor should have absorbed the steered work: {survivor:?}"
    );
}

#[test]
fn delayed_lane_still_shares_work() {
    // A lagging replica slows its lane but must not be starved by the
    // router: static round-robin keeps both lanes fed, which is what the
    // per-replica credit windows account for.
    let shares = [1.0, 0.5];
    let stages = ReplicaFaults::new(SimStages::with_replicas(
        &shares,
        1.0,
        &[1, 2],
    ))
    .delay_on(1, 1, Duration::from_millis(2));
    let engine = engine_over(stages, 4);
    let t = h::seeded_input(8, 4, 9);
    let golden = run_serial(&SimStages::heterogeneous(&shares, 1.0), &t, 1)
        .unwrap()
        .output;
    let run = engine.run(&t).unwrap();
    assert_eq!(run.output, golden);
    for c in engine.replica_counters().iter().filter(|c| c.stage == 1) {
        assert!(
            c.micro_batches >= 2,
            "lane {} starved despite round-robin: {c:?}",
            c.replica
        );
    }
}
