//! Deterministic, seeded engine test harness.
//!
//! Shared by the engine/server integration tests so each test file
//! stops re-declaring the same sim-cluster builders, canned
//! deployments, and fault-injection scaffolding. Everything here is
//! deterministic: inputs come from the in-tree SplitMix64 PRNG keyed by
//! an explicit seed, and the virtual-node substrate's simulated-ms
//! accounting is machine-independent, so assertions on schedules and
//! makespans reproduce exactly across hosts.
#![allow(dead_code)]

use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use amp4ec::cluster::{Cluster, NodeSpec, SimParams};
use amp4ec::deployer::{Deployment, ModelDeployer};
use amp4ec::manifest::Manifest;
use amp4ec::partitioner;
use amp4ec::pipeline::engine::{
    AdaptiveDepthConfig, PersistentEngine, PersistentEngineConfig, SimStages,
    StageExec,
};
use amp4ec::runtime::Tensor;
use amp4ec::scheduler::{Scheduler, ScoringWeights};
use amp4ec::util::rng::Rng;

/// The paper's §IV-B heterogeneous CPU shares.
pub const PAPER_SHARES: &[f64] = &[1.0, 0.6, 0.4];

/// A 5-stage profile with fast early stages and a slow tail — the
/// skewed chain where window *shape* (not just size) decides whether
/// the bottleneck stays fed.
pub const SKEWED_SHARES: &[f64] = &[1.0, 1.0, 1.0, 1.0, 0.3];

/// Deterministic `[rows, cols]` input drawn from the seeded PRNG.
pub fn seeded_input(rows: usize, cols: usize, seed: u64) -> Tensor {
    let mut rng = Rng::new(seed);
    let data = (0..rows * cols).map(|_| rng.f32_range(-4.0, 4.0)).collect();
    Tensor::new(vec![rows, cols], data).unwrap()
}

/// A batch whose every element is `value` — the trigger pattern for
/// [`FaultStages`] sentinels.
pub fn sentinel_input(rows: usize, cols: usize, value: f32) -> Tensor {
    Tensor::new(vec![rows, cols], vec![value; rows * cols]).unwrap()
}

/// The paper's heterogeneous 3-stage sim chain.
pub fn paper_stages(nominal_ms: f64) -> Arc<SimStages> {
    Arc::new(SimStages::heterogeneous(PAPER_SHARES, nominal_ms))
}

/// Arbitrary-profile sim chain (one stage per CPU share).
pub fn sim_stages(shares: &[f64], nominal_ms: f64) -> Arc<SimStages> {
    Arc::new(SimStages::heterogeneous(shares, nominal_ms))
}

/// Fixed-window persistent-engine config at uniform `depth`
/// (micro-batch of 1 row — the engine test default).
pub fn engine_cfg(depth: usize) -> PersistentEngineConfig {
    PersistentEngineConfig {
        micro_batch_rows: 1,
        initial_depth: depth,
        ..Default::default()
    }
}

/// Adaptive-window config: start at `initial`, bounded by `max_depth`.
pub fn adaptive_cfg(initial: usize, max_depth: usize) -> PersistentEngineConfig {
    PersistentEngineConfig {
        micro_batch_rows: 1,
        initial_depth: initial,
        adaptive: Some(AdaptiveDepthConfig {
            max_depth,
            ..AdaptiveDepthConfig::default()
        }),
        ..Default::default()
    }
}

/// Spawn a fixed-window engine over `stages`.
pub fn engine<S: StageExec + Send + Sync + 'static>(
    stages: Arc<S>,
    depth: usize,
) -> PersistentEngine {
    PersistentEngine::new(stages, engine_cfg(depth)).unwrap()
}

/// Fault-injection wrapper around any [`StageExec`]: sentinel-triggered
/// `Err`s or *panics* at a chosen stage (the sentinel is the batch's
/// first element), plus an injectable per-stage wall backlog so tests
/// can drive the adaptive controller's `Executor::queue_depth` veto
/// without a real executor.
pub struct FaultStages<S: StageExec> {
    inner: S,
    fail_at: Option<(usize, f32)>,
    panic_at: Option<(usize, f32)>,
    backlog: Vec<AtomicUsize>,
}

impl<S: StageExec> FaultStages<S> {
    pub fn new(inner: S) -> FaultStages<S> {
        let n = inner.num_stages();
        FaultStages {
            inner,
            fail_at: None,
            panic_at: None,
            backlog: (0..n).map(|_| AtomicUsize::new(0)).collect(),
        }
    }

    /// Error at `stage` whenever the activation's first element equals
    /// `sentinel`.
    pub fn fail_on(mut self, stage: usize, sentinel: f32) -> Self {
        self.fail_at = Some((stage, sentinel));
        self
    }

    /// Panic at `stage` whenever the activation's first element equals
    /// `sentinel` (exercises the engine's catch-unwind isolation).
    pub fn panic_on(mut self, stage: usize, sentinel: f32) -> Self {
        self.panic_at = Some((stage, sentinel));
        self
    }

    /// Inject a wall-clock backlog reading for `stage`.
    pub fn set_backlog(&self, stage: usize, depth: usize) {
        self.backlog[stage].store(depth, Ordering::SeqCst);
    }
}

impl<S: StageExec> FaultStages<S> {
    fn trip_sentinels(&self, stage: usize, input: &Tensor) -> anyhow::Result<()> {
        if let Some((s, v)) = self.fail_at {
            if stage == s && input.data().first() == Some(&v) {
                anyhow::bail!("injected failure at stage {stage}");
            }
        }
        if let Some((s, v)) = self.panic_at {
            if stage == s && input.data().first() == Some(&v) {
                panic!("injected panic at stage {stage}");
            }
        }
        Ok(())
    }
}

impl<S: StageExec> StageExec for FaultStages<S> {
    fn num_stages(&self) -> usize {
        self.inner.num_stages()
    }

    fn node_id(&self, stage: usize) -> usize {
        self.inner.node_id(stage)
    }

    fn comm_in(&self, stage: usize, bytes: u64) -> f64 {
        self.inner.comm_in(stage, bytes)
    }

    fn comm_out(&self, bytes: u64) -> f64 {
        self.inner.comm_out(bytes)
    }

    fn backlog(&self, stage: usize) -> usize {
        self.backlog[stage].load(Ordering::SeqCst)
    }

    // Replica surface: delegate rather than inherit the trait defaults.
    // The defaults collapse everything onto the primary (replicas()==1,
    // execute_on -> execute), which silently un-replicates a replicated
    // inner chain — faults would then never reach replica > 0.
    fn replicas(&self, stage: usize) -> usize {
        self.inner.replicas(stage)
    }

    fn replica_node_id(&self, stage: usize, replica: usize) -> usize {
        self.inner.replica_node_id(stage, replica)
    }

    fn replica_alive(&self, stage: usize, replica: usize) -> bool {
        self.inner.replica_alive(stage, replica)
    }

    fn comm_in_on(&self, stage: usize, replica: usize, bytes: u64) -> f64 {
        self.inner.comm_in_on(stage, replica, bytes)
    }

    fn execute(&self, stage: usize, input: Tensor) -> anyhow::Result<(Tensor, f64)> {
        self.trip_sentinels(stage, &input)?;
        self.inner.execute(stage, input)
    }

    fn execute_on(
        &self,
        stage: usize,
        replica: usize,
        input: Tensor,
    ) -> anyhow::Result<(Tensor, f64)> {
        self.trip_sentinels(stage, &input)?;
        self.inner.execute_on(stage, replica, input)
    }
}

/// Node-churn wrapper around any [`StageExec`]: a per-(stage, replica)
/// kill switch. A killed replica reports not-alive and errors every
/// execute routed to it — the sim twin of a node dropping out
/// mid-stream — until [`KillSwitchStages::revive`] flips it back (warm
/// re-admission). `kill_after` arms a countdown instead: the replica
/// serves N calls and dies *on* call N+1, so a micro-batch is exactly
/// mid-flight when the lights go out.
pub struct KillSwitchStages<S: StageExec> {
    inner: S,
    dead: Vec<Vec<std::sync::atomic::AtomicBool>>,
    /// Calls remaining before auto-kill (`usize::MAX` = never).
    fuse: Vec<Vec<AtomicUsize>>,
}

impl<S: StageExec> KillSwitchStages<S> {
    pub fn new(inner: S) -> KillSwitchStages<S> {
        let shape: Vec<usize> =
            (0..inner.num_stages()).map(|k| inner.replicas(k)).collect();
        KillSwitchStages {
            dead: shape
                .iter()
                .map(|&r| {
                    (0..r)
                        .map(|_| std::sync::atomic::AtomicBool::new(false))
                        .collect()
                })
                .collect(),
            fuse: shape
                .iter()
                .map(|&r| (0..r).map(|_| AtomicUsize::new(usize::MAX)).collect())
                .collect(),
            inner,
        }
    }

    /// Kill `replica` of `stage` now: in-flight and future executes on
    /// it fail, and the alive set stops routing to it.
    pub fn kill(&self, stage: usize, replica: usize) {
        self.dead[stage][replica].store(true, Ordering::SeqCst);
    }

    /// Bring a killed replica back (warm re-admission).
    pub fn revive(&self, stage: usize, replica: usize) {
        self.dead[stage][replica].store(false, Ordering::SeqCst);
        self.fuse[stage][replica].store(usize::MAX, Ordering::SeqCst);
    }

    /// Let `replica` of `stage` serve `calls` executes, then die on the
    /// next one (which fails — that micro-batch was on the node).
    pub fn kill_after(&self, stage: usize, replica: usize, calls: usize) {
        self.fuse[stage][replica].store(calls, Ordering::SeqCst);
    }

    fn gate(&self, stage: usize, replica: usize) -> anyhow::Result<()> {
        if self.dead[stage][replica].load(Ordering::SeqCst) {
            anyhow::bail!("stage {stage} replica {replica} node is gone");
        }
        let armed = self.fuse[stage][replica]
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |n| {
                (n != usize::MAX).then(|| n.saturating_sub(1))
            });
        if armed == Ok(0) {
            self.dead[stage][replica].store(true, Ordering::SeqCst);
            anyhow::bail!(
                "stage {stage} replica {replica} node died mid-stream"
            );
        }
        Ok(())
    }
}

impl<S: StageExec> StageExec for KillSwitchStages<S> {
    fn num_stages(&self) -> usize {
        self.inner.num_stages()
    }

    fn node_id(&self, stage: usize) -> usize {
        self.inner.node_id(stage)
    }

    fn comm_in(&self, stage: usize, bytes: u64) -> f64 {
        self.inner.comm_in(stage, bytes)
    }

    fn comm_out(&self, bytes: u64) -> f64 {
        self.inner.comm_out(bytes)
    }

    fn backlog(&self, stage: usize) -> usize {
        self.inner.backlog(stage)
    }

    fn replicas(&self, stage: usize) -> usize {
        self.inner.replicas(stage)
    }

    fn replica_node_id(&self, stage: usize, replica: usize) -> usize {
        self.inner.replica_node_id(stage, replica)
    }

    fn replica_alive(&self, stage: usize, replica: usize) -> bool {
        !self.dead[stage][replica].load(Ordering::SeqCst)
            && self.inner.replica_alive(stage, replica)
    }

    fn comm_in_on(&self, stage: usize, replica: usize, bytes: u64) -> f64 {
        self.inner.comm_in_on(stage, replica, bytes)
    }

    fn execute(&self, stage: usize, input: Tensor) -> anyhow::Result<(Tensor, f64)> {
        self.gate(stage, 0)?;
        self.inner.execute(stage, input)
    }

    fn execute_on(
        &self,
        stage: usize,
        replica: usize,
        input: Tensor,
    ) -> anyhow::Result<(Tensor, f64)> {
        self.gate(stage, replica)?;
        self.inner.execute_on(stage, replica, input)
    }
}

/// Canned artifact-gated deployment: the manifest at batch 1 over the
/// paper's heterogeneous trio (equal-split partition plan).
pub fn deploy_paper_cluster(artifacts: &Path) -> (Deployment, Arc<ModelDeployer>) {
    let manifest = Arc::new(Manifest::load(artifacts).unwrap());
    let cluster = Cluster::new(SimParams::default());
    cluster.add_node(NodeSpec::new("edge-high", 1.0, 1024.0));
    cluster.add_node(NodeSpec::new("edge-med", 0.6, 512.0));
    cluster.add_node(NodeSpec::new("edge-low", 0.4, 512.0));
    let scheduler = Scheduler::new(ScoringWeights::default());
    let plan = partitioner::plan(&manifest, 3).unwrap();
    let deployer = Arc::new(ModelDeployer::new(Arc::clone(&manifest)));
    let dep = deployer.deploy(&plan, &cluster, &scheduler, 1).unwrap();
    (dep, deployer)
}
