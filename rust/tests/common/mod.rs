//! Shared helpers for integration tests: artifact gating plus the
//! deterministic engine harness (`harness`).

pub mod harness;

use std::path::PathBuf;

pub fn artifacts_dir() -> PathBuf {
    // cargo test runs from the workspace root.
    std::env::var_os("AMP4EC_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// Skip (return true) when artifacts haven't been built. CI environments
/// must run `make artifacts` first; unit tests never require artifacts.
pub fn artifacts_missing() -> bool {
    !artifacts_dir().join("manifest.json").exists()
}

#[macro_export]
macro_rules! require_artifacts {
    () => {
        if common::artifacts_missing() {
            eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
            return;
        }
    };
}
