//! ISSUE 5 tentpole tests: the zero-copy activation data plane.
//!
//! The Arc-backed tensor refactor must be a pure *mechanism* change:
//! every view-based path (stack, micro-batch split, reassembly, member
//! re-split, per-request row split, coalescing) has to stay
//! bit-identical to the copying implementations it replaced. The
//! copying oracles live right here, so the equivalence is pinned
//! against the old semantics, not against the new code. On top of that:
//! zero-copy pinning (`Arc::ptr_eq` — a split/slice really shares its
//! parent buffer) and the aliasing test (mutating a served output can
//! never alter a cached row).

mod common;

use common::harness as h;

use std::sync::Arc;
use std::time::Duration;

use amp4ec::pipeline::engine::{
    concat_rows, run_serial, split_rows, PersistentEngine,
    PersistentEngineConfig,
};
use amp4ec::pipeline::{split_batch, stack_batch};
use amp4ec::runtime::Tensor;
use amp4ec::scheduler::cache::{input_key, ResultCache};
use amp4ec::serving::{EngineService, IngressConfig, Outcome, ServiceHandle};
use amp4ec::util::check::forall;
use amp4ec::util::rng::Rng;

// ---------------------------------------------------------------------------
// Copying oracles: the pre-refactor implementations, verbatim semantics
// ---------------------------------------------------------------------------

/// The old `split_rows`: memcpy every chunk out of the batch.
fn oracle_split_rows(t: &Tensor, chunk_rows: usize) -> Vec<Tensor> {
    let rows = t.shape[0];
    let row_len: usize = t.shape.iter().skip(1).product();
    let mut out = Vec::new();
    let mut r = 0;
    while r < rows {
        let take = chunk_rows.min(rows - r);
        let mut shape = t.shape.clone();
        shape[0] = take;
        out.push(
            Tensor::new(
                shape,
                t.data()[r * row_len..(r + take) * row_len].to_vec(),
            )
            .unwrap(),
        );
        r += take;
    }
    out
}

/// The old `concat_rows`: memcpy every chunk into a fresh buffer.
fn oracle_concat_rows(chunks: &[Tensor]) -> Tensor {
    let mut rows = 0;
    let mut data = Vec::new();
    for c in chunks {
        rows += c.shape[0];
        data.extend_from_slice(c.data());
    }
    let mut shape = chunks[0].shape.clone();
    shape[0] = rows;
    Tensor::new(shape, data).unwrap()
}

/// The old `stack_batch`: memcpy rows + zero-fill padding.
fn oracle_stack_batch(inputs: &[&Tensor], batch: usize) -> Tensor {
    let per = &inputs[0].shape;
    let row_len: usize = per.iter().skip(1).product();
    let mut data = Vec::with_capacity(batch * row_len);
    for t in inputs {
        data.extend_from_slice(t.data());
    }
    data.resize(batch * row_len, 0.0);
    let mut shape = per.clone();
    shape[0] = batch;
    Tensor::new(shape, data).unwrap()
}

/// The old `split_batch`: memcpy each row back out.
fn oracle_split_batch(output: &Tensor, n: usize) -> Vec<Tensor> {
    let row_len: usize = output.shape.iter().skip(1).product();
    let mut shape = output.shape.clone();
    shape[0] = 1;
    (0..n)
        .map(|i| {
            Tensor::new(
                shape.clone(),
                output.data()[i * row_len..(i + 1) * row_len].to_vec(),
            )
            .unwrap()
        })
        .collect()
}

fn rand_tensor(rng: &mut Rng, rows: usize, cols: usize) -> Tensor {
    let data =
        (0..rows * cols).map(|_| rng.f32_range(-8.0, 8.0)).collect();
    Tensor::new(vec![rows, cols], data).unwrap()
}

// ---------------------------------------------------------------------------
// Property: view-based primitives are bit-identical to the copying oracles
// ---------------------------------------------------------------------------

#[test]
fn property_split_concat_roundtrips_match_oracles() {
    forall(60, 0xDA7A, |rng| {
        let rows = rng.range(1, 12);
        let cols = rng.range(1, 9);
        let chunk = rng.range(1, rows + 2);
        let t = rand_tensor(rng, rows, cols);

        let views = split_rows(&t, chunk).unwrap();
        let copies = oracle_split_rows(&t, chunk);
        assert_eq!(views.len(), copies.len());
        for (v, c) in views.iter().zip(&copies) {
            assert_eq!(v, c, "split_rows diverged from the copying oracle");
            // Zero-copy pinned: every chunk is a window into the batch.
            assert!(
                Arc::ptr_eq(v.buf(), t.buf()),
                "split_rows copied a chunk"
            );
        }
        // Roundtrip both ways, and cross: views reassembled must equal
        // the oracle reassembly of the oracle chunks.
        assert_eq!(concat_rows(&views).unwrap(), t);
        assert_eq!(oracle_concat_rows(&copies), t);
        assert_eq!(concat_rows(&copies).unwrap(), oracle_concat_rows(&views));
    });
}

#[test]
fn property_stack_and_split_batch_match_oracles() {
    forall(60, 0x57AC, |rng| {
        let n = rng.range(1, 7);
        let cols = rng.range(1, 10);
        let batch = n + rng.below(4);
        let inputs: Vec<Tensor> =
            (0..n).map(|_| rand_tensor(rng, 1, cols)).collect();
        let refs: Vec<&Tensor> = inputs.iter().collect();

        let stacked = stack_batch(&refs, batch).unwrap();
        assert_eq!(stacked, oracle_stack_batch(&refs, batch));

        let rows = split_batch(&stacked, n).unwrap();
        let oracle_rows = oracle_split_batch(&stacked, n);
        for ((r, o), original) in rows.iter().zip(&oracle_rows).zip(&inputs) {
            assert_eq!(r, o, "split_batch diverged from the copying oracle");
            assert_eq!(r, original, "row did not roundtrip");
            assert!(
                Arc::ptr_eq(r.buf(), stacked.buf()),
                "split_batch copied a row"
            );
        }
    });
}

#[test]
fn stack_batch_fast_paths_share_buffers() {
    // A lone padding-free input passes through as a view.
    let one = h::seeded_input(1, 6, 11);
    let stacked = stack_batch(&[&one], 1).unwrap();
    assert!(Arc::ptr_eq(stacked.buf(), one.buf()));
    // Rows split off one batch re-stack as a view of that batch.
    let batch = h::seeded_input(4, 6, 12);
    let rows = split_batch(&batch, 4).unwrap();
    let refs: Vec<&Tensor> = rows.iter().collect();
    let restacked = stack_batch(&refs, 4).unwrap();
    assert!(
        Arc::ptr_eq(restacked.buf(), batch.buf()),
        "contiguous re-stack must be a view"
    );
    assert_eq!(restacked, batch);
    // Out-of-order rows are not contiguous: the copying path kicks in
    // and still matches the oracle.
    let shuffled = [&rows[2], &rows[0], &rows[1], &rows[3]];
    let copied = stack_batch(&shuffled, 4).unwrap();
    assert!(!Arc::ptr_eq(copied.buf(), batch.buf()));
    assert_eq!(copied, oracle_stack_batch(&shuffled, 4));
}

// ---------------------------------------------------------------------------
// Property: the engine's micro-batch/coalesce path stays bit-identical
// ---------------------------------------------------------------------------

#[test]
fn property_engine_micro_batching_bit_identical_to_serial() {
    forall(12, 0xE9E1, |rng| {
        let rows = rng.range(1, 9);
        let cols = rng.range(1, 17);
        let micro = rng.range(1, 4);
        let depth = rng.range(1, 5);
        let t = rand_tensor(rng, rows, cols);
        let stages = h::paper_stages(0.5);
        let want = run_serial(&*stages, &t, rows).unwrap().output;
        let engine = PersistentEngine::new(
            h::paper_stages(0.5),
            PersistentEngineConfig {
                micro_batch_rows: micro,
                initial_depth: depth,
                ..Default::default()
            },
        )
        .unwrap();
        let got = engine.run(&t).unwrap().output;
        assert_eq!(got, want, "micro-batched output diverged from serial");
    });
}

#[test]
fn property_coalesced_transports_bit_identical_and_addressable() {
    forall(10, 0xC0A1, |rng| {
        let cols = rng.range(1, 9);
        let n_batches = rng.range(2, 6);
        let batches: Vec<Tensor> = (0..n_batches)
            .map(|_| rand_tensor(rng, rng.range(1, 4), cols))
            .collect();
        let stages = h::paper_stages(0.5);
        let want: Vec<Tensor> = batches
            .iter()
            .map(|b| run_serial(&*stages, b, b.shape[0]).unwrap().output)
            .collect();
        let engine = PersistentEngine::new(
            h::paper_stages(0.5),
            PersistentEngineConfig {
                micro_batch_rows: 4,
                initial_depth: 2,
                coalesce: true,
                ..Default::default()
            },
        )
        .unwrap();
        let handles: Vec<_> = batches
            .iter()
            .map(|b| engine.submit(b).unwrap())
            .collect();
        for (hd, want) in handles.into_iter().zip(&want) {
            let run = hd.wait().unwrap();
            assert_eq!(
                &run.output, want,
                "coalesced member output diverged (not batch-addressable)"
            );
        }
    });
}

// ---------------------------------------------------------------------------
// Aliasing: a cached row can never be altered through a served output
// ---------------------------------------------------------------------------

#[test]
fn mutating_a_served_output_never_alters_the_cached_row() {
    let engine = PersistentEngine::new(
        h::sim_stages(h::PAPER_SHARES, 0.5),
        PersistentEngineConfig {
            micro_batch_rows: 1,
            initial_depth: 2,
            ..Default::default()
        },
    )
    .unwrap();
    let cache = Arc::new(ResultCache::new(16));
    let handle = ServiceHandle::new(
        Arc::new(EngineService::new(Arc::new(engine), 1, 2)),
        IngressConfig {
            max_wait: Duration::from_millis(1),
            ..IngressConfig::default()
        },
        Some(Arc::clone(&cache)),
    );
    let input = h::seeded_input(1, 8, 77);
    let mut first = match handle.submit(input.clone()).unwrap().wait() {
        Outcome::Done(r) => {
            assert!(!r.cache_hit);
            r.output
        }
        other => panic!("unexpected outcome {other:?}"),
    };
    let honest = first.clone();
    // Stomp the served output through the copy-on-write path: the
    // response row is a view into the batch output, and the cached row
    // must own separate storage.
    for v in first.data_mut() {
        *v = -1234.5;
    }
    let second = match handle.submit(input.clone()).unwrap().wait() {
        Outcome::Done(r) => {
            assert!(r.cache_hit, "repeat input must hit the cache");
            r.output
        }
        other => panic!("unexpected outcome {other:?}"),
    };
    assert_eq!(
        second, honest,
        "mutating a served output leaked into the cached row"
    );
    // And the hit itself is zero-copy: the response wraps the cache's
    // shared buffer.
    let key = input_key(0xE5E5, input.data());
    let stored = cache.get(key).expect("row cached");
    assert!(
        Arc::ptr_eq(&stored, second.buf()),
        "cache hit should hand back the stored buffer as a view"
    );
    drop(handle);
}

#[test]
fn cached_hit_survives_recycling_of_the_batch_buffer() {
    // A cache-hit tensor keeps its buffer alive independently of the
    // serving path's pooling/recycling: wait for two hits on the same
    // key and check both views read identically.
    let engine = PersistentEngine::new(
        h::sim_stages(h::PAPER_SHARES, 0.5),
        PersistentEngineConfig {
            micro_batch_rows: 1,
            initial_depth: 2,
            ..Default::default()
        },
    )
    .unwrap();
    let cache = Arc::new(ResultCache::new(8));
    let handle = ServiceHandle::new(
        Arc::new(EngineService::new(Arc::new(engine), 1, 2)),
        IngressConfig {
            max_wait: Duration::from_millis(1),
            ..IngressConfig::default()
        },
        Some(cache),
    );
    let input = h::seeded_input(1, 8, 78);
    let miss = handle.submit(input.clone()).unwrap().wait_output().unwrap();
    let hit1 = handle.submit(input.clone()).unwrap().wait_output().unwrap();
    let hit2 = handle.submit(input).unwrap().wait_output().unwrap();
    assert_eq!(miss, hit1);
    assert_eq!(hit1, hit2);
    // The two hits share one stored buffer (zero-copy), yet equal the
    // original miss bit-for-bit.
    assert!(Arc::ptr_eq(hit1.buf(), hit2.buf()));
    drop(handle);
}

// ---------------------------------------------------------------------------
// Artifact-gated: the real-model pipeline stays golden through the
// view-based data plane
// ---------------------------------------------------------------------------

#[test]
fn real_model_golden_parity_through_view_data_plane() {
    require_artifacts!();
    let cfg =
        amp4ec::config::AmpConfig::paper_cluster(&common::artifacts_dir());
    let server = amp4ec::server::EdgeServer::start(cfg).unwrap();
    // Golden parity rides the full ingress → stack → engine → reassembly
    // → row-split path; a view-aliasing bug anywhere in it shows up as a
    // golden mismatch.
    server.golden_check().unwrap();
}
