//! Scheduler micro-benchmarks + weighted-scoring ablation.
//!
//! The paper reports a flat 10 ms scheduling overhead; the NSA decision
//! itself must be microseconds so the overhead budget is all batching
//! window. Also sweeps the Eq. 4 weights (the paper calls its 0.2/0.2/
//! 0.1/0.5 "experimentally determined") and reports how placement skew
//! responds. `cargo bench --bench scheduler`.

use std::sync::Arc;

use amp4ec::cluster::{NodeSpec, SimParams, VirtualNode};
use amp4ec::metrics::markdown_table;
use amp4ec::scheduler::{Scheduler, ScoringWeights, TaskRequirements};
use amp4ec::util::bench::BenchSuite;

fn mk_cluster(n: usize) -> Vec<Arc<VirtualNode>> {
    (0..n)
        .map(|i| {
            let cpu = [1.0, 0.6, 0.4][i % 3];
            Arc::new(VirtualNode::new(
                i,
                NodeSpec::new(&format!("n{i}"), cpu, 512.0 + (i % 2) as f64 * 512.0),
                SimParams { runtime_overhead_mb: 0.0, ..SimParams::default() },
            ))
        })
        .collect()
}

fn placement_distribution(weights: ScoringWeights, tasks: usize) -> Vec<u64> {
    let sched = Scheduler::new(weights);
    let nodes = mk_cluster(3);
    let req = TaskRequirements::default();
    let mut counts = vec![0u64; 3];
    // FIFO in-flight model: up to 4 tasks run concurrently; the oldest
    // dispatched finishes first, with exec time inversely proportional to
    // the node's CPU share (feeds the performance history).
    let mut inflight: std::collections::VecDeque<usize> =
        std::collections::VecDeque::new();
    for _ in 0..tasks {
        let (node, _) = sched.select_node(&nodes, &req).unwrap();
        counts[node.id()] += 1;
        sched.task_started(node.id());
        inflight.push_back(node.id());
        if inflight.len() > 4 {
            let done = inflight.pop_front().unwrap();
            let cpu = nodes[done].spec().cpu_fraction;
            sched.task_completed(done, 50.0 / cpu);
        }
    }
    counts
}

fn main() {
    let mut suite = BenchSuite::new("scheduler");

    for n in [3usize, 10, 50, 200] {
        let nodes = mk_cluster(n);
        let sched = Scheduler::new(ScoringWeights::default());
        let req = TaskRequirements::default();
        suite.bench(&format!("NSA select_node over {n} nodes"), 100, 2000, || {
            std::hint::black_box(sched.select_node(&nodes, &req));
        });
    }

    // Decision latency must be a rounding error against the paper's 10 ms
    // scheduling overhead budget.
    assert!(
        suite.results().iter().all(|r| r.mean_ms < 1.0),
        "NSA decision must be sub-millisecond"
    );

    // ---- ablation: scoring weights -------------------------------------
    let sweeps: Vec<(&str, ScoringWeights)> = vec![
        ("paper 0.2/0.2/0.1/0.5",
         ScoringWeights { resource: 0.2, load: 0.2, performance: 0.1, balance: 0.5 }),
        ("resource-heavy 0.7/0.1/0.1/0.1",
         ScoringWeights { resource: 0.7, load: 0.1, performance: 0.1, balance: 0.1 }),
        ("balance-only 0/0/0/1",
         ScoringWeights { resource: 0.0, load: 0.0, performance: 0.0, balance: 1.0 }),
        ("uniform 0.25x4",
         ScoringWeights { resource: 0.25, load: 0.25, performance: 0.25, balance: 0.25 }),
    ];
    let mut rows = Vec::new();
    for (name, w) in &sweeps {
        let counts = placement_distribution(*w, 300);
        let max = *counts.iter().max().unwrap() as f64;
        let min = *counts.iter().min().unwrap() as f64;
        rows.push(vec![
            name.to_string(),
            format!("{counts:?}"),
            format!("{:.2}", if min > 0.0 { max / min } else { f64::INFINITY }),
        ]);
    }
    println!(
        "{}",
        markdown_table(
            "Ablation — Eq. 4 scoring weights vs placement skew (300 tasks, 3 heterogeneous nodes)",
            &["Weights", "Tasks per node", "Max/min skew"],
            &rows,
        )
    );

    // The paper's balance-dominated weighting should spread load across
    // all nodes (no starvation).
    let paper_counts = placement_distribution(ScoringWeights::default(), 300);
    assert!(
        paper_counts.iter().all(|&c| c > 0),
        "paper weights must not starve any node: {paper_counts:?}"
    );
}
