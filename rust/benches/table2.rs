//! Table II regeneration: resource profiles and performance.
//!
//! Paper: High (1.0 CPU, 1GB) 234.56 ms; Medium (0.6, 512MB) 389.27 ms;
//! Low (0.4, 512MB) 583.91 ms — ratios 1 : 1.66 : 2.49, which track the
//! inverse CPU shares. The bench reproduces ordering + ratios on the
//! virtual cluster and asserts the shape. `cargo bench --bench table2`.

use amp4ec::cluster::Profile;
use amp4ec::config::AmpConfig;
use amp4ec::metrics::markdown_table;
use amp4ec::server::{single_request, EdgeServer};
use amp4ec::util::stats::Summary;
use amp4ec::workload::InputPool;

const ITERATIONS: usize = 30;

fn measure(profile: Profile) -> Summary {
    let cfg = AmpConfig::profile_cluster(&amp4ec::artifacts_dir(), profile, 3);
    let server = EdgeServer::start(cfg).unwrap();
    let pool = InputPool::new(&server.request_shape(), 4, 201);
    let mut lat = Summary::new();
    single_request(&server, pool.get(0)).unwrap(); // warm-up
    for i in 0..ITERATIONS {
        let (_, ms) = single_request(&server, pool.get(i)).unwrap();
        lat.record(ms);
    }
    lat
}

fn main() {
    eprintln!("table2: sweeping 3 resource profiles x {ITERATIONS} iterations...");
    let profiles = [
        (Profile::High, 234.56),
        (Profile::Medium, 389.27),
        (Profile::Low, 583.91),
    ];
    let mut results = Vec::new();
    for (p, paper_ms) in profiles {
        let lat = measure(p);
        results.push((p, paper_ms, lat));
    }

    let high_mean = results[0].2.mean();
    let rows: Vec<Vec<String>> = results
        .iter()
        .map(|(p, paper_ms, lat)| {
            let spec = p.spec();
            vec![
                p.name().to_string(),
                format!("{}", spec.cpu_fraction),
                format!("{}", spec.mem_limit_mb),
                format!("{:.2}", lat.mean()),
                format!("{:.2}", lat.p50()),
                format!("{:.2}x", lat.mean() / high_mean),
                format!("{paper_ms:.2}"),
                format!("{:.2}x", paper_ms / 234.56),
            ]
        })
        .collect();
    println!(
        "{}",
        markdown_table(
            "Table II — resource profiles and performance",
            &[
                "Profile", "CPU", "Mem MB", "Measured mean (ms)",
                "Measured p50 (ms)", "Ratio", "Paper (ms)", "Paper ratio"
            ],
            &rows,
        )
    );

    // Shape assertions: strict ordering High < Medium < Low, and the
    // Medium/Low ratios within 40% of the paper's (which equal inverse
    // CPU shares).
    let (h, m, l) = (results[0].2.mean(), results[1].2.mean(), results[2].2.mean());
    assert!(h < m && m < l, "profile ordering violated: {h} {m} {l}");
    let med_ratio = m / h;
    let low_ratio = l / h;
    assert!(
        (med_ratio - 1.66).abs() / 1.66 < 0.4,
        "Medium ratio {med_ratio:.2} too far from paper 1.66"
    );
    assert!(
        (low_ratio - 2.49).abs() / 2.49 < 0.4,
        "Low ratio {low_ratio:.2} too far from paper 2.49"
    );
    eprintln!("table2: shape assertions PASSED");
}
