//! Streaming engine vs serial pipeline on a heterogeneous 3-stage
//! cluster (the paper's 1.0/0.6/0.4 CPU profile).
//!
//! Runs entirely on the virtual-node substrate (no PJRT artifacts):
//! each stage applies a row-wise transform with a fixed nominal compute
//! cost, dilated by its node's CPU quota, so serial execution costs the
//! *sum* of the stage times per micro-batch while the streamed engine
//! approaches the *max* (the pipeline bound). Asserts the acceptance
//! criteria of ISSUE 1 (streamed outputs bit-identical to serial,
//! streamed throughput strictly better with >= 4 micro-batches in
//! flight), ISSUE 2 (persistent cross-batch streaming >= 20% over
//! per-super-batch streaming at depth >= 4; adaptive depth within 1 of
//! the best fixed depth), ISSUE 3 (profile-shaped per-stage credit
//! windows >= 10% simulated throughput over the equal-credit global
//! window on a skewed 5-stage chain), and ISSUE 5 (zero-copy data
//! plane: >= 50% fewer copied activation bytes than the pre-refactor
//! copying path on a wide-activation profile at depth 4, with no
//! streaming-throughput regression). Emits `BENCH_pipeline.json`,
//! `BENCH_api.json`, and `BENCH_dataplane.json`. `cargo bench --bench
//! pipeline_engine`.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use amp4ec::metrics::markdown_table;
use amp4ec::pipeline::engine::{
    budgets_from_profile, run_serial, run_streamed, AdaptiveDepthConfig,
    EngineConfig, PersistentEngine, PersistentEngineConfig, SimStages,
};
use amp4ec::runtime::Tensor;
use amp4ec::serving::{
    class_name, EngineService, IngressConfig, Priority, ServiceHandle,
};
use amp4ec::util::bench::BenchSuite;
use amp4ec::util::json::Json;

fn input(rows: usize, cols: usize) -> Tensor {
    let data = (0..rows * cols).map(|i| (i as f32) * 0.125 - 4.0).collect();
    Tensor::new(vec![rows, cols], data).unwrap()
}

fn input_off(rows: usize, cols: usize, off: f32) -> Tensor {
    let data = (0..rows * cols)
        .map(|i| (i as f32) * 0.125 - 4.0 + off)
        .collect();
    Tensor::new(vec![rows, cols], data).unwrap()
}

fn main() {
    let mut suite = BenchSuite::new("pipeline_engine");

    // The paper's heterogeneous cluster; 4 ms nominal per stage becomes
    // 4 / 6.7 / 10 ms of simulated compute across the three nodes.
    let stages = SimStages::heterogeneous(&[1.0, 0.6, 0.4], 4.0);
    let batch = input(8, 64); // 8 micro-batches of 1 row each

    // ---- serial comparator --------------------------------------------
    let t0 = Instant::now();
    let serial = run_serial(&stages, &batch, 1).expect("serial run");
    let serial_wall_ms = t0.elapsed().as_secs_f64() * 1e3;

    // ---- streamed, >= 4 micro-batches in flight -----------------------
    let cfg = EngineConfig { micro_batch_rows: 1, max_in_flight: 4 };
    let t0 = Instant::now();
    let streamed = run_streamed(&stages, &batch, &cfg).expect("streamed run");
    let streamed_wall_ms = t0.elapsed().as_secs_f64() * 1e3;

    // Bit-identical outputs (row-wise stages): the engine must be a pure
    // scheduling change, never a numerics change.
    assert_eq!(
        serial.output, streamed.output,
        "streamed output must be bit-identical to serial"
    );

    let serial_sim = serial.timing.total_ms;
    let streamed_sim = streamed.timing.total_ms;
    let speedup = serial_sim / streamed_sim;
    suite.record_value("serial sim total", serial_sim, "ms");
    suite.record_value("streamed sim total", streamed_sim, "ms");
    suite.record_value("serial wall", serial_wall_ms, "ms");
    suite.record_value("streamed wall", streamed_wall_ms, "ms");
    suite.record_value("sim speedup", speedup, "x");
    suite.record_value(
        "serial throughput",
        8.0 / (serial_sim / 1e3),
        "rows/s",
    );
    suite.record_value(
        "streamed throughput",
        8.0 / (streamed_sim / 1e3),
        "rows/s",
    );

    assert!(
        streamed_sim < serial_sim,
        "streamed {streamed_sim:.2} ms must beat serial {serial_sim:.2} ms"
    );
    assert!(
        speedup > 1.3,
        "expected a clear pipeline win on 1.0/0.6/0.4, got {speedup:.2}x"
    );
    assert!(
        streamed_wall_ms < serial_wall_ms,
        "wall clock must agree with sim: streamed {streamed_wall_ms:.1} ms \
         vs serial {serial_wall_ms:.1} ms"
    );

    // ---- per-stage occupancy ------------------------------------------
    let rows: Vec<Vec<String>> = streamed
        .stage_counters
        .iter()
        .map(|c| {
            vec![
                format!("{}", c.stage),
                format!("{}", c.node),
                format!("{:.1}", c.busy_ms),
                format!("{:.1}", c.bubble_ms),
                format!("{:.0}%", 100.0 * c.occupancy(streamed_sim)),
                format!("{}", c.micro_batches),
            ]
        })
        .collect();
    println!(
        "{}",
        markdown_table(
            "Streamed per-stage occupancy (8 micro-batches, depth 4)",
            &["Stage", "Node", "Busy ms", "Bubble ms", "Occupancy", "Micro-batches"],
            &rows,
        )
    );
    // The slowest stage (0.4 CPU) is the bottleneck: it should be nearly
    // always busy in the streamed schedule.
    let bottleneck = streamed
        .stage_counters
        .last()
        .expect("3 stages");
    assert!(
        bottleneck.occupancy(streamed_sim) > 0.6,
        "bottleneck stage occupancy {:.2} too low",
        bottleneck.occupancy(streamed_sim)
    );

    // ---- depth sweep ---------------------------------------------------
    let mut sweep_rows = Vec::new();
    for depth in [1usize, 2, 4, 8] {
        let cfg = EngineConfig { micro_batch_rows: 1, max_in_flight: depth };
        let run = run_streamed(&stages, &batch, &cfg).expect("sweep run");
        assert_eq!(run.output, serial.output);
        sweep_rows.push(vec![
            format!("{depth}"),
            format!("{:.1}", run.timing.total_ms),
            format!("{:.2}x", serial_sim / run.timing.total_ms),
        ]);
    }
    println!(
        "{}",
        markdown_table(
            "Depth sweep vs serial (sim ms)",
            &["Max in flight", "Sim total ms", "Speedup vs serial"],
            &sweep_rows,
        )
    );

    // ---- ISSUE 2: persistent cross-batch vs per-super-batch -----------
    // Same heterogeneous profile, lighter nominal cost so the multi-batch
    // sweeps stay fast. Per-super-batch = one `run_streamed` call per
    // batch (PR 1's serving path: full fill+drain every batch);
    // persistent = the same batches submitted back-to-back into one
    // long-lived engine.
    let nominal_ms = 2.0;
    let micro_per_batch = 4usize;
    let n_batches = 10usize;
    let batches: Vec<Tensor> = (0..n_batches)
        .map(|i| input_off(micro_per_batch, 64, i as f32))
        .collect();
    let total_rows = (n_batches * micro_per_batch) as f64;

    let serial_stages = SimStages::heterogeneous(&[1.0, 0.6, 0.4], nominal_ms);
    let serial_outputs: Vec<Tensor> = batches
        .iter()
        .map(|b| run_serial(&serial_stages, b, 1).expect("serial").output)
        .collect();

    let mut table_rows = Vec::new();
    let mut json_rows = Vec::new();
    let mut improvement_at = BTreeMap::new();
    for depth in [1usize, 2, 4, 8] {
        // Per-super-batch streaming: fresh fill+drain per batch.
        let stages =
            SimStages::heterogeneous(&[1.0, 0.6, 0.4], nominal_ms);
        let cfg = EngineConfig { micro_batch_rows: 1, max_in_flight: depth };
        let mut per_batch_ms = 0.0;
        for (b, want) in batches.iter().zip(&serial_outputs) {
            let run = run_streamed(&stages, b, &cfg).expect("per-batch run");
            assert_eq!(&run.output, want, "per-batch output diverged");
            per_batch_ms += run.timing.total_ms;
        }

        // Persistent cross-batch streaming: same batches, no drain.
        let engine = PersistentEngine::new(
            Arc::new(SimStages::heterogeneous(&[1.0, 0.6, 0.4], nominal_ms)),
            PersistentEngineConfig {
                micro_batch_rows: 1,
                initial_depth: depth,
                adaptive: None,
                ..Default::default()
            },
        )
        .expect("engine");
        let handles: Vec<_> = batches
            .iter()
            .map(|b| engine.submit(b).expect("submit"))
            .collect();
        for (h, want) in handles.into_iter().zip(&serial_outputs) {
            let run = h.wait().expect("persistent run");
            assert_eq!(&run.output, want, "persistent output diverged");
        }
        let persistent_ms = engine.makespan_ms();
        let totals = engine.total_counters();
        let bottleneck = totals
            .iter()
            .max_by(|a, b| a.busy_ms.total_cmp(&b.busy_ms))
            .expect("stages");
        let bubble_pct = 100.0 * bottleneck.bubble_fraction();

        let improvement = per_batch_ms / persistent_ms - 1.0;
        improvement_at.insert(depth, improvement);
        table_rows.push(vec![
            format!("{depth}"),
            format!("{:.1}", per_batch_ms),
            format!("{:.1}", persistent_ms),
            format!("{:.1}%", improvement * 100.0),
            format!("{bubble_pct:.1}%"),
        ]);
        let mut row = BTreeMap::new();
        row.insert("depth".into(), Json::from(depth));
        row.insert("per_batch_sim_ms".into(), Json::Num(per_batch_ms));
        row.insert("persistent_sim_ms".into(), Json::Num(persistent_ms));
        row.insert(
            "per_batch_rows_per_s".into(),
            Json::Num(total_rows / (per_batch_ms / 1e3)),
        );
        row.insert(
            "persistent_rows_per_s".into(),
            Json::Num(total_rows / (persistent_ms / 1e3)),
        );
        row.insert(
            "improvement_pct".into(),
            Json::Num(improvement * 100.0),
        );
        row.insert(
            "bottleneck_bubble_pct".into(),
            Json::Num(bubble_pct),
        );
        json_rows.push(Json::Obj(row));
        suite.record_value(
            &format!("persistent throughput d{depth}"),
            total_rows / (persistent_ms / 1e3),
            "rows/s",
        );
    }
    println!(
        "{}",
        markdown_table(
            "Persistent cross-batch vs per-super-batch streaming (sim ms)",
            &[
                "Depth",
                "Per-batch total",
                "Persistent total",
                "Improvement",
                "Bottleneck bubble",
            ],
            &table_rows,
        )
    );
    // The ISSUE-2 acceptance gate: >= 20% simulated-throughput win at
    // depth >= 4 from eliminating inter-batch drain bubbles.
    for depth in [4usize, 8] {
        let imp = improvement_at[&depth];
        assert!(
            imp >= 0.20,
            "persistent streaming at depth {depth} improved only \
             {:.1}% (< 20%)",
            imp * 100.0
        );
    }

    // ---- adaptive depth convergence ------------------------------------
    // Best fixed depth: smallest depth within 2% of the best cross-batch
    // makespan over 1..=8.
    let conv_batches: Vec<Tensor> = (0..8)
        .map(|i| input_off(micro_per_batch, 16, i as f32))
        .collect();
    let mut fixed: Vec<(usize, f64)> = Vec::new();
    for depth in 1..=8usize {
        let engine = PersistentEngine::new(
            Arc::new(SimStages::heterogeneous(&[1.0, 0.6, 0.4], nominal_ms)),
            PersistentEngineConfig {
                micro_batch_rows: 1,
                initial_depth: depth,
                adaptive: None,
                ..Default::default()
            },
        )
        .expect("engine");
        let handles: Vec<_> = conv_batches
            .iter()
            .map(|b| engine.submit(b).expect("submit"))
            .collect();
        for h in handles {
            h.wait().expect("run");
        }
        fixed.push((depth, engine.makespan_ms()));
    }
    let best_ms = fixed.iter().map(|(_, ms)| *ms).fold(f64::INFINITY, f64::min);
    let best_depth = fixed
        .iter()
        .find(|(_, ms)| *ms <= best_ms * 1.02)
        .map(|(d, _)| *d)
        .expect("best depth");

    let engine = PersistentEngine::new(
        Arc::new(SimStages::heterogeneous(&[1.0, 0.6, 0.4], nominal_ms)),
        PersistentEngineConfig {
            micro_batch_rows: 1,
            initial_depth: 1,
            adaptive: Some(AdaptiveDepthConfig {
                max_depth: 8,
                ..AdaptiveDepthConfig::default()
            }),
            ..Default::default()
        },
    )
    .expect("engine");
    let mut handles = Vec::new();
    for _round in 0..3 {
        for b in &conv_batches {
            handles.push(engine.submit(b).expect("submit"));
        }
    }
    for h in handles {
        h.wait().expect("run");
    }
    let adaptive_report = engine.depth_report();
    let final_depth = engine.current_depth();
    suite.record_value("best fixed depth", best_depth as f64, "");
    suite.record_value("adaptive final depth", final_depth as f64, "");
    assert!(
        (final_depth as i64 - best_depth as i64).abs() <= 1,
        "adaptive depth {final_depth} not within 1 of best fixed \
         {best_depth} (sweep {fixed:?}, report {adaptive_report:?})"
    );

    // ---- ISSUE 3: per-stage credit windows vs the global window --------
    // Skewed chain (four fast stages feeding a slow tail): at the same
    // total credit capacity, profile-shaped per-stage budgets give the
    // delivery window the credits the fast stages don't need, so the
    // bottleneck runs at its true rate where the equal-split global
    // window throttles admission to window/latency. Acceptance gate:
    // >= 10% simulated throughput.
    let skew_shares = [1.0, 1.0, 1.0, 1.0, 0.3];
    let skew_nominal = 2.0;
    let skew_batches: Vec<Tensor> =
        (0..12).map(|i| input_off(4, 32, i as f32)).collect();
    let skew_rows: f64 =
        skew_batches.iter().map(|b| b.shape[0] as f64).sum();
    let uniform_depth = 2usize;
    let total_credits = uniform_depth * skew_shares.len();

    let skew_serial: Vec<Tensor> = {
        let stages = SimStages::heterogeneous(&skew_shares, skew_nominal);
        skew_batches
            .iter()
            .map(|b| run_serial(&stages, b, 1).expect("skew serial").output)
            .collect()
    };

    // Probe the per-stage latency profile (compute + ingress comm per
    // micro-batch) with one batch at the uniform window, then shape the
    // same credit total from it.
    let probe = PersistentEngine::new(
        Arc::new(SimStages::heterogeneous(&skew_shares, skew_nominal)),
        PersistentEngineConfig {
            micro_batch_rows: 1,
            initial_depth: uniform_depth,
            adaptive: None,
            ..Default::default()
        },
    )
    .expect("probe engine");
    let probe_run = probe.run(&skew_batches[0]).expect("probe run");
    let latencies: Vec<f64> = probe_run
        .stage_counters
        .iter()
        .map(|c| (c.busy_ms + c.comm_ms) / c.micro_batches.max(1) as f64)
        .collect();
    drop(probe);
    let shaped = budgets_from_profile(&latencies, total_credits);
    assert_eq!(shaped.iter().sum::<usize>(), total_credits);

    let run_skew = |engine: &PersistentEngine| -> f64 {
        let handles: Vec<_> = skew_batches
            .iter()
            .map(|b| engine.submit(b).expect("skew submit"))
            .collect();
        for (h, want) in handles.into_iter().zip(&skew_serial) {
            let run = h.wait().expect("skew run");
            assert_eq!(&run.output, want, "skewed output diverged");
        }
        engine.makespan_ms()
    };

    let global = PersistentEngine::new(
        Arc::new(SimStages::heterogeneous(&skew_shares, skew_nominal)),
        PersistentEngineConfig {
            micro_batch_rows: 1,
            initial_depth: uniform_depth,
            adaptive: None,
            ..Default::default()
        },
    )
    .expect("global engine");
    let global_ms = run_skew(&global);

    let per_stage = PersistentEngine::new(
        Arc::new(SimStages::heterogeneous(&skew_shares, skew_nominal)),
        PersistentEngineConfig {
            micro_batch_rows: 1,
            initial_depth: *shaped.last().expect("stages"),
            stage_budgets: Some(shaped.clone()),
            adaptive: None,
            ..Default::default()
        },
    )
    .expect("per-stage engine");
    let per_stage_ms = run_skew(&per_stage);

    let window_win = global_ms / per_stage_ms - 1.0;
    println!(
        "{}",
        markdown_table(
            "Per-stage credit windows vs global window (skewed 5-stage, \
             equal credit totals)",
            &["Windows", "Budgets", "Sim total ms", "Rows/s"],
            &[
                vec![
                    "global".into(),
                    format!("[{uniform_depth}; {}]", skew_shares.len()),
                    format!("{global_ms:.1}"),
                    format!("{:.1}", skew_rows / (global_ms / 1e3)),
                ],
                vec![
                    "per-stage".into(),
                    format!("{shaped:?}"),
                    format!("{per_stage_ms:.1}"),
                    format!("{:.1}", skew_rows / (per_stage_ms / 1e3)),
                ],
            ],
        )
    );
    suite.record_value(
        "global-window throughput (skewed)",
        skew_rows / (global_ms / 1e3),
        "rows/s",
    );
    suite.record_value(
        "per-stage throughput (skewed)",
        skew_rows / (per_stage_ms / 1e3),
        "rows/s",
    );
    suite.record_value("per-stage window win", window_win * 100.0, "%");
    // The ISSUE-3 acceptance gate.
    assert!(
        window_win >= 0.10,
        "per-stage windows improved only {:.1}% (< 10%) over the global \
         window on the skewed profile (budgets {shaped:?})",
        window_win * 100.0
    );

    // ---- ISSUE 4: two-class serving through the unified ingress --------
    // A saturated engine served through the request-level API: a
    // best-effort flood plus a high-priority deadline class. The
    // high-priority lane jumps both the ingress queue and the engine
    // feeder, so its p99 must beat the best-effort p99 under identical
    // load; a best-effort-only control run shows what the same deadline
    // looks like without priority (sheds/misses). Emits per-class
    // p50/p99 and shed counts to BENCH_api.json.
    use std::time::Duration;
    let api_engine = || {
        Arc::new(
            PersistentEngine::new(
                Arc::new(SimStages::heterogeneous(&[1.0, 0.25], 1.0)),
                PersistentEngineConfig {
                    micro_batch_rows: 1,
                    initial_depth: 1,
                    adaptive: None,
                    ..Default::default()
                },
            )
            .expect("api engine"),
        )
    };
    let api_input = |i: usize| input_off(1, 32, i as f32);
    let flood_n = 40usize;
    let hi_n = 6usize;
    let deadline = Duration::from_millis(150);

    // Mixed run: flood + high-priority deadline class.
    let handle = ServiceHandle::new(
        Arc::new(EngineService::new(api_engine(), 1, 1)),
        IngressConfig {
            workers: 1,
            max_wait: Duration::from_millis(1),
            ..IngressConfig::default()
        },
        None,
    );
    let flood: Vec<_> = (0..flood_n)
        .map(|i| {
            handle
                .request(api_input(i))
                .priority(Priority::BEST_EFFORT)
                .submit()
                .expect("flood submit")
        })
        .collect();
    let urgent: Vec<_> = (0..hi_n)
        .map(|i| {
            handle
                .request(api_input(flood_n + i))
                .priority(Priority::HIGH)
                .deadline(deadline)
                .submit()
                .expect("urgent submit")
        })
        .collect();
    for u in urgent {
        u.wait();
    }
    for f in flood {
        f.wait();
    }
    let mixed = handle.finish();
    let hi = mixed.class(Priority::HIGH.class()).expect("high class");
    let be = mixed
        .class(Priority::BEST_EFFORT.class())
        .expect("best-effort class");
    let hi_lat = hi.latency_summary();
    let be_lat = be.latency_summary();

    // Control: the same flood best-effort-only, every request carrying
    // the deadline the high class met.
    let control = ServiceHandle::new(
        Arc::new(EngineService::new(api_engine(), 1, 1)),
        IngressConfig {
            workers: 1,
            max_wait: Duration::from_millis(1),
            ..IngressConfig::default()
        },
        None,
    );
    let rs: Vec<_> = (0..flood_n + hi_n)
        .map(|i| {
            control
                .request(api_input(i))
                .priority(Priority::BEST_EFFORT)
                .deadline(deadline)
                .submit()
                .expect("control submit")
        })
        .collect();
    for r in rs {
        r.wait();
    }
    let control_m = control.finish();
    let cbe = control_m
        .class(Priority::BEST_EFFORT.class())
        .expect("control class");

    println!(
        "{}",
        markdown_table(
            "Two-class serving under saturation (wall ms, deadline 150 ms)",
            &["Class", "Completed", "Shed", "p50", "p99", "Deadlines met"],
            &[
                vec![
                    "high".into(),
                    format!("{}", hi.completed),
                    format!("{}", hi.shed()),
                    format!("{:.1}", hi_lat.p50()),
                    format!("{:.1}", hi_lat.p99()),
                    format!("{}/{}", hi.deadline_met, hi.deadline_total),
                ],
                vec![
                    "best-effort".into(),
                    format!("{}", be.completed),
                    format!("{}", be.shed()),
                    format!("{:.1}", be_lat.p50()),
                    format!("{:.1}", be_lat.p99()),
                    "-".into(),
                ],
                vec![
                    "best-effort only (control)".into(),
                    format!("{}", cbe.completed),
                    format!("{}", cbe.shed()),
                    format!("{:.1}", cbe.latency_summary().p50()),
                    format!("{:.1}", cbe.latency_summary().p99()),
                    format!("{}/{}", cbe.deadline_met, cbe.deadline_total),
                ],
            ],
        )
    );
    suite.record_value("high-priority p99", hi_lat.p99(), "ms");
    suite.record_value("best-effort p99", be_lat.p99(), "ms");
    assert_eq!(hi.completed as usize, hi_n, "high-priority requests lost");
    assert_eq!(
        hi.deadline_met, hi.deadline_total,
        "high-priority class missed its deadline under saturation"
    );
    assert!(
        hi_lat.p99() < be_lat.p99(),
        "priority lane p99 {:.1} ms must beat best-effort p99 {:.1} ms",
        hi_lat.p99(),
        be_lat.p99()
    );
    assert!(
        cbe.shed() > 0 || cbe.deadline_met < cbe.deadline_total,
        "the best-effort-only control should miss the deadline the \
         high-priority class met"
    );

    let class_json = |c: &amp4ec::metrics::ClassMetrics| {
        let lat = c.latency_summary();
        let mut j = BTreeMap::new();
        j.insert("class".into(), Json::from(c.class));
        j.insert("name".into(), Json::Str(class_name(c.class)));
        j.insert("completed".into(), Json::from(c.completed as usize));
        j.insert("shed_expired".into(), Json::from(c.shed_expired as usize));
        j.insert(
            "shed_predicted".into(),
            Json::from(c.shed_predicted as usize),
        );
        j.insert("p50_ms".into(), Json::Num(lat.p50()));
        j.insert("p99_ms".into(), Json::Num(lat.p99()));
        j.insert("deadline_met".into(), Json::from(c.deadline_met as usize));
        j.insert(
            "deadline_total".into(),
            Json::from(c.deadline_total as usize),
        );
        Json::Obj(j)
    };
    let mut api_doc = BTreeMap::new();
    api_doc.insert("suite".into(), Json::Str("serving_api".into()));
    api_doc.insert("deadline_ms".into(), Json::Num(150.0));
    api_doc.insert("flood_requests".into(), Json::from(flood_n));
    api_doc.insert("high_priority_requests".into(), Json::from(hi_n));
    api_doc.insert(
        "mixed".into(),
        Json::Arr(vec![class_json(hi), class_json(be)]),
    );
    api_doc.insert(
        "best_effort_only".into(),
        Json::Arr(vec![class_json(cbe)]),
    );
    std::fs::write("BENCH_api.json", Json::Obj(api_doc).to_string())
        .expect("write BENCH_api.json");
    println!("wrote BENCH_api.json");

    // ---- ISSUE 5: zero-copy data plane on a wide-activation profile ----
    // Wide rows are where the data plane's memcpy tax dominates: at
    // 4096 f32/row every stack/split/reassembly copy moves 16 KiB per
    // row. (a) engine-level: serial vs persistent streaming at depth 4
    // on the wide profile (sim throughput must still win — views must
    // not cost schedule quality). (b) serving-level: a request flood
    // through the full ingress with the process-global
    // `metrics::data_plane` counters snapshotted around it; the copied
    // bytes are gated at >= 50% below what the pre-refactor copying
    // path moved for the same traffic (reconstructed from the run's own
    // activation accounting — see `naive_copied` below). Emits
    // `BENCH_dataplane.json`.
    use amp4ec::metrics::data_plane;
    use amp4ec::util::pool::BufferPool;

    let wide_shares = [1.0, 0.8, 0.6, 0.4];
    let wide_cols = 4096usize;
    let wide_nominal = 1.0;

    // (a) engine-level wide-activation throughput, depth 4 vs serial.
    let wide_batches: Vec<Tensor> =
        (0..6).map(|i| input_off(8, wide_cols, i as f32)).collect();
    let wide_rows: f64 =
        wide_batches.iter().map(|b| b.shape[0] as f64).sum();
    let wide_stages = SimStages::heterogeneous(&wide_shares, wide_nominal);
    let mut wide_serial_ms = 0.0;
    let wide_serial_out: Vec<Tensor> = wide_batches
        .iter()
        .map(|b| {
            let run = run_serial(&wide_stages, b, 1).expect("wide serial");
            wide_serial_ms += run.timing.total_ms;
            run.output
        })
        .collect();
    let wide_engine = PersistentEngine::new(
        Arc::new(SimStages::heterogeneous(&wide_shares, wide_nominal)),
        PersistentEngineConfig {
            micro_batch_rows: 1,
            initial_depth: 4,
            adaptive: None,
            ..Default::default()
        },
    )
    .expect("wide engine");
    let wide_handles: Vec<_> = wide_batches
        .iter()
        .map(|b| wide_engine.submit(b).expect("wide submit"))
        .collect();
    for (h, want) in wide_handles.into_iter().zip(&wide_serial_out) {
        let run = h.wait().expect("wide run");
        assert_eq!(
            &run.output, want,
            "wide-activation view path diverged from serial"
        );
    }
    let wide_persistent_ms = wide_engine.makespan_ms();
    drop(wide_engine);
    let wide_win = wide_serial_ms / wide_persistent_ms - 1.0;
    suite.record_value(
        "wide serial throughput",
        wide_rows / (wide_serial_ms / 1e3),
        "rows/s",
    );
    suite.record_value(
        "wide streamed throughput (d4)",
        wide_rows / (wide_persistent_ms / 1e3),
        "rows/s",
    );
    assert!(
        wide_win >= 0.10,
        "wide-activation depth-4 streaming improved only {:.1}% (< 10%) \
         over serial — the zero-copy plane must not cost throughput",
        wide_win * 100.0
    );

    // (b) serving-level copy accounting: a flood of wide single-row
    // requests through the full request path (clone at submit, stack,
    // micro-batch split, engine traversal, reassembly, per-request row
    // split).
    let dp_requests = 32usize;
    let row_bytes = (wide_cols * 4) as u64;
    let dp_engine = Arc::new(
        PersistentEngine::new(
            Arc::new(SimStages::heterogeneous(&wide_shares, wide_nominal)),
            PersistentEngineConfig {
                micro_batch_rows: 1,
                initial_depth: 4,
                adaptive: None,
                ..Default::default()
            },
        )
        .expect("dataplane engine"),
    );
    let dp_handle = ServiceHandle::new(
        Arc::new(EngineService::new(dp_engine, 1, 4)),
        IngressConfig {
            workers: 2,
            max_wait: Duration::from_millis(1),
            ..IngressConfig::default()
        },
        None,
    );
    let dp_inputs: Vec<Tensor> =
        (0..dp_requests).map(|i| input_off(1, wide_cols, i as f32)).collect();
    let before = data_plane::snapshot();
    let pool_before = BufferPool::global().stats();
    let dp_t0 = Instant::now();
    let rs: Vec<_> = dp_inputs
        .iter()
        .map(|t| dp_handle.submit(t.clone()).expect("dataplane submit"))
        .collect();
    for r in rs {
        r.wait_output().expect("dataplane response");
    }
    let dp_wall_ms = dp_t0.elapsed().as_secs_f64() * 1e3;
    let dp_metrics = dp_handle.finish();
    let moved = data_plane::snapshot().since(&before);
    let pool = {
        let after = BufferPool::global().stats();
        (
            after.hits - pool_before.hits,
            after.misses - pool_before.misses,
            after.returns - pool_before.returns,
        )
    };
    assert_eq!(dp_metrics.completed as usize, dp_requests);

    // What the pre-refactor copying plane moved for this exact traffic:
    // `activation_bytes` is the serving layer's Σ(stacked + output)
    // bytes, so Σ stacked == Σ output == activation_bytes / 2. Old
    // copies: engine split_rows (Σ stacked) + collector concat
    // (Σ output) + per-request submit clone (N rows) + stack_batch real
    // rows (N rows) + response row split (N rows).
    let naive_copied =
        dp_metrics.activation_bytes + 3 * dp_requests as u64 * row_bytes;
    let reduction = 1.0 - moved.copied_bytes as f64 / naive_copied as f64;
    println!(
        "{}",
        markdown_table(
            "Zero-copy data plane (32 wide requests, 4096 f32/row, depth 4)",
            &["Metric", "Value"],
            &[
                vec![
                    "copied bytes (view plane)".into(),
                    format!("{}", moved.copied_bytes),
                ],
                vec![
                    "copied bytes (pre-refactor plane)".into(),
                    format!("{naive_copied}"),
                ],
                vec![
                    "reduction".into(),
                    format!("{:.1}%", reduction * 100.0),
                ],
                vec![
                    "bytes shared as views".into(),
                    format!("{}", moved.viewed_bytes),
                ],
                vec![
                    "copy ops".into(),
                    format!("{}", moved.copies),
                ],
                vec![
                    "pool hits/misses/returns".into(),
                    format!("{}/{}/{}", pool.0, pool.1, pool.2),
                ],
            ],
        )
    );
    suite.record_value(
        "dataplane copied",
        moved.copied_bytes as f64 / 1024.0,
        "KiB",
    );
    suite.record_value("dataplane copy reduction", reduction * 100.0, "%");
    // The ISSUE-5 acceptance gate: >= 50% fewer data-plane copied bytes
    // than the copying implementation for identical traffic.
    assert!(
        reduction >= 0.50,
        "data plane copied {} of a naive {} bytes — only {:.1}% \
         reduction (< 50%)",
        moved.copied_bytes,
        naive_copied,
        reduction * 100.0
    );
    // Views did real work: at minimum every micro-batch split and every
    // response row was shared instead of copied.
    assert!(
        moved.viewed_bytes >= dp_requests as u64 * row_bytes,
        "view accounting looks broken: {} bytes",
        moved.viewed_bytes
    );

    let mut dp_doc = BTreeMap::new();
    dp_doc.insert("suite".into(), Json::Str("dataplane".into()));
    dp_doc.insert("row_len".into(), Json::from(wide_cols));
    dp_doc.insert("depth".into(), Json::from(4usize));
    dp_doc.insert("requests".into(), Json::from(dp_requests));
    dp_doc.insert(
        "copied_bytes".into(),
        Json::from(moved.copied_bytes as usize),
    );
    dp_doc.insert(
        "naive_copied_bytes".into(),
        Json::from(naive_copied as usize),
    );
    dp_doc.insert(
        "reduction_pct".into(),
        Json::Num(reduction * 100.0),
    );
    dp_doc.insert(
        "viewed_bytes".into(),
        Json::from(moved.viewed_bytes as usize),
    );
    dp_doc.insert("copy_ops".into(), Json::from(moved.copies as usize));
    dp_doc.insert("pool_hits".into(), Json::from(pool.0 as usize));
    dp_doc.insert("pool_misses".into(), Json::from(pool.1 as usize));
    dp_doc.insert("pool_returns".into(), Json::from(pool.2 as usize));
    dp_doc.insert(
        "serving_wall_ms".into(),
        Json::Num(dp_wall_ms),
    );
    dp_doc.insert(
        "serving_rows_per_s".into(),
        Json::Num(dp_requests as f64 / (dp_wall_ms / 1e3)),
    );
    dp_doc.insert(
        "wide_serial_sim_ms".into(),
        Json::Num(wide_serial_ms),
    );
    dp_doc.insert(
        "wide_streamed_sim_ms".into(),
        Json::Num(wide_persistent_ms),
    );
    dp_doc.insert(
        "wide_serial_rows_per_s".into(),
        Json::Num(wide_rows / (wide_serial_ms / 1e3)),
    );
    dp_doc.insert(
        "wide_streamed_rows_per_s".into(),
        Json::Num(wide_rows / (wide_persistent_ms / 1e3)),
    );
    dp_doc.insert(
        "wide_improvement_pct".into(),
        Json::Num(wide_win * 100.0),
    );
    std::fs::write("BENCH_dataplane.json", Json::Obj(dp_doc).to_string())
        .expect("write BENCH_dataplane.json");
    println!("wrote BENCH_dataplane.json");

    // ---- machine-readable trajectory -----------------------------------
    let mut doc = BTreeMap::new();
    doc.insert("suite".into(), Json::Str("pipeline_engine".into()));
    doc.insert(
        "cpu_shares".into(),
        Json::Arr(vec![Json::Num(1.0), Json::Num(0.6), Json::Num(0.4)]),
    );
    doc.insert("nominal_ms".into(), Json::Num(nominal_ms));
    doc.insert("micro_per_batch".into(), Json::from(micro_per_batch));
    doc.insert("n_batches".into(), Json::from(n_batches));
    doc.insert("depths".into(), Json::Arr(json_rows));
    let mut adaptive = BTreeMap::new();
    adaptive.insert("best_fixed_depth".into(), Json::from(best_depth));
    adaptive.insert("final_depth".into(), Json::from(final_depth));
    adaptive.insert(
        "initial_depth".into(),
        Json::from(adaptive_report.initial_depth),
    );
    adaptive.insert(
        "widenings".into(),
        Json::from(adaptive_report.widenings as usize),
    );
    adaptive.insert(
        "narrowings".into(),
        Json::from(adaptive_report.narrowings as usize),
    );
    doc.insert("adaptive".into(), Json::Obj(adaptive));
    let mut per_stage_doc = BTreeMap::new();
    per_stage_doc.insert(
        "skew_cpu_shares".into(),
        Json::Arr(skew_shares.iter().map(|&s| Json::Num(s)).collect()),
    );
    per_stage_doc.insert(
        "budgets".into(),
        Json::Arr(shaped.iter().map(|&b| Json::from(b)).collect()),
    );
    per_stage_doc.insert("uniform_depth".into(), Json::from(uniform_depth));
    per_stage_doc.insert("global_sim_ms".into(), Json::Num(global_ms));
    per_stage_doc.insert("per_stage_sim_ms".into(), Json::Num(per_stage_ms));
    per_stage_doc.insert(
        "global_rows_per_s".into(),
        Json::Num(skew_rows / (global_ms / 1e3)),
    );
    per_stage_doc.insert(
        "per_stage_rows_per_s".into(),
        Json::Num(skew_rows / (per_stage_ms / 1e3)),
    );
    per_stage_doc.insert(
        "improvement_pct".into(),
        Json::Num(window_win * 100.0),
    );
    doc.insert("per_stage_windows".into(), Json::Obj(per_stage_doc));
    std::fs::write("BENCH_pipeline.json", Json::Obj(doc).to_string())
        .expect("write BENCH_pipeline.json");
    println!("wrote BENCH_pipeline.json");
}
