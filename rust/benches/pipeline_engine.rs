//! Streaming engine vs serial pipeline on a heterogeneous 3-stage
//! cluster (the paper's 1.0/0.6/0.4 CPU profile).
//!
//! Runs entirely on the virtual-node substrate (no PJRT artifacts):
//! each stage applies a row-wise transform with a fixed nominal compute
//! cost, dilated by its node's CPU quota, so serial execution costs the
//! *sum* of the stage times per micro-batch while the streamed engine
//! approaches the *max* (the pipeline bound). Asserts the acceptance
//! criteria of ISSUE 1: streamed outputs bit-identical to serial, and
//! streamed throughput strictly better with >= 4 micro-batches in
//! flight. `cargo bench --bench pipeline_engine`.

use std::time::Instant;

use amp4ec::metrics::markdown_table;
use amp4ec::pipeline::engine::{
    run_serial, run_streamed, EngineConfig, SimStages,
};
use amp4ec::runtime::Tensor;
use amp4ec::util::bench::BenchSuite;

fn input(rows: usize, cols: usize) -> Tensor {
    let data = (0..rows * cols).map(|i| (i as f32) * 0.125 - 4.0).collect();
    Tensor::new(vec![rows, cols], data).unwrap()
}

fn main() {
    let mut suite = BenchSuite::new("pipeline_engine");

    // The paper's heterogeneous cluster; 4 ms nominal per stage becomes
    // 4 / 6.7 / 10 ms of simulated compute across the three nodes.
    let stages = SimStages::heterogeneous(&[1.0, 0.6, 0.4], 4.0);
    let batch = input(8, 64); // 8 micro-batches of 1 row each

    // ---- serial comparator --------------------------------------------
    let t0 = Instant::now();
    let serial = run_serial(&stages, &batch, 1).expect("serial run");
    let serial_wall_ms = t0.elapsed().as_secs_f64() * 1e3;

    // ---- streamed, >= 4 micro-batches in flight -----------------------
    let cfg = EngineConfig { micro_batch_rows: 1, max_in_flight: 4 };
    let t0 = Instant::now();
    let streamed = run_streamed(&stages, &batch, &cfg).expect("streamed run");
    let streamed_wall_ms = t0.elapsed().as_secs_f64() * 1e3;

    // Bit-identical outputs (row-wise stages): the engine must be a pure
    // scheduling change, never a numerics change.
    assert_eq!(
        serial.output, streamed.output,
        "streamed output must be bit-identical to serial"
    );

    let serial_sim = serial.timing.total_ms;
    let streamed_sim = streamed.timing.total_ms;
    let speedup = serial_sim / streamed_sim;
    suite.record_value("serial sim total", serial_sim, "ms");
    suite.record_value("streamed sim total", streamed_sim, "ms");
    suite.record_value("serial wall", serial_wall_ms, "ms");
    suite.record_value("streamed wall", streamed_wall_ms, "ms");
    suite.record_value("sim speedup", speedup, "x");
    suite.record_value(
        "serial throughput",
        8.0 / (serial_sim / 1e3),
        "rows/s",
    );
    suite.record_value(
        "streamed throughput",
        8.0 / (streamed_sim / 1e3),
        "rows/s",
    );

    assert!(
        streamed_sim < serial_sim,
        "streamed {streamed_sim:.2} ms must beat serial {serial_sim:.2} ms"
    );
    assert!(
        speedup > 1.3,
        "expected a clear pipeline win on 1.0/0.6/0.4, got {speedup:.2}x"
    );
    assert!(
        streamed_wall_ms < serial_wall_ms,
        "wall clock must agree with sim: streamed {streamed_wall_ms:.1} ms \
         vs serial {serial_wall_ms:.1} ms"
    );

    // ---- per-stage occupancy ------------------------------------------
    let rows: Vec<Vec<String>> = streamed
        .stage_counters
        .iter()
        .map(|c| {
            vec![
                format!("{}", c.stage),
                format!("{}", c.node),
                format!("{:.1}", c.busy_ms),
                format!("{:.1}", c.bubble_ms),
                format!("{:.0}%", 100.0 * c.occupancy(streamed_sim)),
                format!("{}", c.micro_batches),
            ]
        })
        .collect();
    println!(
        "{}",
        markdown_table(
            "Streamed per-stage occupancy (8 micro-batches, depth 4)",
            &["Stage", "Node", "Busy ms", "Bubble ms", "Occupancy", "Micro-batches"],
            &rows,
        )
    );
    // The slowest stage (0.4 CPU) is the bottleneck: it should be nearly
    // always busy in the streamed schedule.
    let bottleneck = streamed
        .stage_counters
        .last()
        .expect("3 stages");
    assert!(
        bottleneck.occupancy(streamed_sim) > 0.6,
        "bottleneck stage occupancy {:.2} too low",
        bottleneck.occupancy(streamed_sim)
    );

    // ---- depth sweep ---------------------------------------------------
    let mut sweep_rows = Vec::new();
    for depth in [1usize, 2, 4, 8] {
        let cfg = EngineConfig { micro_batch_rows: 1, max_in_flight: depth };
        let run = run_streamed(&stages, &batch, &cfg).expect("sweep run");
        assert_eq!(run.output, serial.output);
        sweep_rows.push(vec![
            format!("{depth}"),
            format!("{:.1}", run.timing.total_ms),
            format!("{:.2}x", serial_sim / run.timing.total_ms),
        ]);
    }
    println!(
        "{}",
        markdown_table(
            "Depth sweep vs serial (sim ms)",
            &["Max in flight", "Sim total ms", "Speedup vs serial"],
            &sweep_rows,
        )
    );
}
