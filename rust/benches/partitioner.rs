//! §IV-D regeneration + partitioner micro-benchmarks + ablations.
//!
//! Paper §IV-D: "In the two-part configuration, the partition sizes were
//! optimally determined as [116, 25]. For the three-part configuration, a
//! balanced distribution was achieved with partition sizes of
//! [108, 16, 17]." Both reproduce *exactly* from the Eq. 1/2/9 cost model
//! over the 141-entry module list.
//!
//! Ablations: capability-weighted targets, the corrected (group-aware)
//! cost model, and scoring-weight sweeps. `cargo bench --bench partitioner`.

use amp4ec::manifest::Manifest;
use amp4ec::metrics::markdown_table;
use amp4ec::partitioner::{self, cost};
use amp4ec::util::bench::BenchSuite;

fn main() {
    let m = Manifest::load(&amp4ec::artifacts_dir())
        .expect("run `make artifacts` first");

    // ---- §IV-D table ---------------------------------------------------
    let mut rows = Vec::new();
    for (parts, paper) in [(2usize, "[116, 25]"), (3, "[108, 16, 17]"), (4, "-")] {
        let plan = partitioner::plan(&m, parts).unwrap();
        rows.push(vec![
            format!("{parts}"),
            format!("{:?}", plan.layer_sizes()),
            paper.to_string(),
            format!("{:?}", plan.block_ranges()),
            format!("{:.3}", plan.imbalance()),
            format!(
                "{:?}",
                plan.comm_bytes(&m, 1)
                    .iter()
                    .map(|b| format!("{:.1}KB", *b as f64 / 1e3))
                    .collect::<Vec<_>>()
            ),
        ]);
    }
    println!(
        "{}",
        markdown_table(
            "§IV-D — model partitioning results",
            &["Partitions", "Layer sizes (ours)", "Layer sizes (paper)",
              "Block ranges", "Cost imbalance", "Cut activations"],
            &rows,
        )
    );
    let p2 = partitioner::plan(&m, 2).unwrap().layer_sizes();
    let p3 = partitioner::plan(&m, 3).unwrap().layer_sizes();
    assert_eq!(p2, vec![116, 25], "2-part must match paper exactly");
    assert_eq!(p3, vec![108, 16, 17], "3-part must match paper exactly");
    eprintln!("partitioner: paper §IV-D sizes reproduced EXACTLY");

    // ---- ablation: cost model ------------------------------------------
    let mut ab = Vec::new();
    for parts in [2usize, 3] {
        let paper_cost = partitioner::plan(&m, parts).unwrap().layer_sizes();
        let flops = partitioner::layer_sizes_flops_cost(&m, parts);
        ab.push(vec![
            format!("{parts}"),
            format!("{paper_cost:?}"),
            format!("{flops:?}"),
        ]);
    }
    println!(
        "{}",
        markdown_table(
            "Ablation — paper cost (Eq. 9, depthwise overcounted) vs group-aware FLOPs cost",
            &["Partitions", "Paper cost model", "Group-aware cost model"],
            &ab,
        )
    );

    // ---- ablation: capability weighting --------------------------------
    let mut wrows = Vec::new();
    for weights in [vec![1.0, 1.0, 1.0], vec![1.0, 0.6, 0.4], vec![2.0, 1.0, 1.0]] {
        let plan = partitioner::plan_weighted(&m, &weights).unwrap();
        let costs: Vec<u64> = plan.partitions.iter().map(|p| p.cost).collect();
        let total: u64 = costs.iter().sum();
        wrows.push(vec![
            format!("{weights:?}"),
            format!("{:?}", plan.layer_sizes()),
            format!(
                "{:?}",
                costs
                    .iter()
                    .map(|c| format!("{:.0}%", 100.0 * *c as f64 / total as f64))
                    .collect::<Vec<_>>()
            ),
        ]);
    }
    println!(
        "{}",
        markdown_table(
            "Ablation — capability-weighted partition targets",
            &["Node CPU weights", "Layer sizes", "Cost shares"],
            &wrows,
        )
    );

    // ---- micro-benchmarks ----------------------------------------------
    let mut suite = BenchSuite::new("partitioner");
    suite.bench("plan(2 partitions)", 10, 200, || {
        std::hint::black_box(partitioner::plan(&m, 2).unwrap());
    });
    suite.bench("plan(3 partitions)", 10, 200, || {
        std::hint::black_box(partitioner::plan(&m, 3).unwrap());
    });
    suite.bench("plan_weighted(3)", 10, 200, || {
        std::hint::black_box(
            partitioner::plan_weighted(&m, &[1.0, 0.6, 0.4]).unwrap(),
        );
    });
    let layers = m.flat_layers();
    suite.bench("cost model over 141 layers", 10, 500, || {
        let total: u64 = layers.iter().map(|l| cost::layer_cost(l)).sum();
        std::hint::black_box(total);
    });
    // The paper reports 10 ms scheduling overhead; partition planning must
    // be far below that to be a non-factor at redeploy time.
    assert!(
        suite.results()[0].mean_ms < 10.0,
        "partition planning should be well under the paper's 10 ms overhead"
    );
}
