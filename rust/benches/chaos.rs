//! Chaos gate + straggler-hedging bench (ISSUE 10).
//!
//! Three acceptance gates, all asserted (a regression fails the bench,
//! not just a number drifting):
//!
//! 1. **Degeneracy pin** — a clean wire run (no proxy, no deadline) is
//!    bit-identical to the in-process chain, same sim makespan.
//! 2. **Chaos gate** — the same workload through a seeded byte-level
//!    fault proxy (adversarial fragmentation + random delays on one
//!    stage's link, execute deadline armed) completes with zero hangs
//!    and bit-identical outputs: benign chaos must be invisible.
//! 3. **Hedging gate** — with one replica lane of the bottleneck stage
//!    turned into a straggler, hedging-on p99 batch latency must be at
//!    most `HEDGE_P99_BOUND_X` of hedging-off p99, outputs still
//!    bit-identical to the serial reference.
//!
//! Emits `BENCH_chaos.json`. `cargo bench --bench chaos`.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use amp4ec::pipeline::engine::{
    run_serial, HedgeConfig, PersistentEngine, PersistentEngineConfig,
    SimStages, StageExec,
};
use amp4ec::runtime::Tensor;
use amp4ec::transport::agent::NodeAgent;
use amp4ec::transport::chaos::{ChaosProxy, ConnPlans, FaultPlan};
use amp4ec::transport::WireStages;
use amp4ec::util::bench::BenchSuite;
use amp4ec::util::json::Json;

const SHARES: &[f64] = &[1.0, 0.6, 0.4];
const NOMINAL_MS: f64 = 1.0;
const COLS: usize = 8;
const ROWS_PER_BATCH: usize = 5;
const N_BATCHES: usize = 10;
const DEPTH: usize = 4;
/// Hard no-hang gate for the chaotic run's total wall time.
const CHAOS_WALL_BOUND_MS: f64 = 30_000.0;

/// Hedging workload: the bottleneck stage runs two replicas, one of
/// which stalls `STRAGGLER_LAG_MS` of wall clock per execution once
/// armed.
const HEDGE_SHARES: &[f64] = &[1.0, 0.25, 1.0];
const STRAGGLER_LAG_MS: u64 = 150;
const HEDGE_WARMUP_BATCHES: usize = 4;
const HEDGE_MEASURED_BATCHES: usize = 24;
/// Stated acceptance bound: hedging-on p99 / hedging-off p99.
const HEDGE_P99_BOUND_X: f64 = 0.5;

fn batches() -> Vec<Tensor> {
    (0..N_BATCHES)
        .map(|b| {
            let data = (0..ROWS_PER_BATCH * COLS)
                .map(|i| (i as f32) * 0.0625 - 2.0 + b as f32)
                .collect();
            Tensor::new(vec![ROWS_PER_BATCH, COLS], data).unwrap()
        })
        .collect()
}

fn engine_cfg(hedge: Option<HedgeConfig>) -> PersistentEngineConfig {
    PersistentEngineConfig {
        micro_batch_rows: 1,
        initial_depth: DEPTH,
        adaptive: None,
        hedge,
        ..Default::default()
    }
}

/// Stream every batch through `engine`; returns (outputs, wall ms,
/// final sim makespan).
fn drive(engine: &PersistentEngine, inputs: &[Tensor]) -> (Vec<Tensor>, f64, f64) {
    let t0 = Instant::now();
    let handles: Vec<_> = inputs
        .iter()
        .map(|b| engine.submit(b).expect("submit"))
        .collect();
    let outputs: Vec<Tensor> = handles
        .into_iter()
        .map(|h| h.wait().expect("batch").output)
        .collect();
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    (outputs, wall_ms, engine.makespan_ms())
}

fn percentile(samples: &[f64], q: f64) -> f64 {
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx]
}

/// Replica-aware straggler wrapper: once armed, every execution on one
/// lane stalls for `lag` of wall clock (correct but slow).
struct LaggyStages {
    inner: SimStages,
    lane: (usize, usize),
    lag: Duration,
    armed: Arc<AtomicBool>,
}

impl LaggyStages {
    fn bottleneck_pair(armed: Arc<AtomicBool>) -> LaggyStages {
        LaggyStages {
            inner: SimStages::with_replicas(HEDGE_SHARES, NOMINAL_MS, &[1, 2, 1]),
            lane: (1, 0),
            lag: Duration::from_millis(STRAGGLER_LAG_MS),
            armed,
        }
    }
}

impl StageExec for LaggyStages {
    fn num_stages(&self) -> usize {
        self.inner.num_stages()
    }

    fn node_id(&self, stage: usize) -> usize {
        self.inner.node_id(stage)
    }

    fn comm_in(&self, stage: usize, bytes: u64) -> f64 {
        self.inner.comm_in(stage, bytes)
    }

    fn comm_out(&self, bytes: u64) -> f64 {
        self.inner.comm_out(bytes)
    }

    fn execute(&self, stage: usize, input: Tensor) -> anyhow::Result<(Tensor, f64)> {
        self.execute_on(stage, 0, input)
    }

    fn replicas(&self, stage: usize) -> usize {
        self.inner.replicas(stage)
    }

    fn replica_node_id(&self, stage: usize, replica: usize) -> usize {
        self.inner.replica_node_id(stage, replica)
    }

    fn comm_in_on(&self, stage: usize, replica: usize, bytes: u64) -> f64 {
        self.inner.comm_in_on(stage, replica, bytes)
    }

    fn execute_on(
        &self,
        stage: usize,
        replica: usize,
        input: Tensor,
    ) -> anyhow::Result<(Tensor, f64)> {
        if (stage, replica) == self.lane && self.armed.load(Ordering::SeqCst) {
            std::thread::sleep(self.lag);
        }
        self.inner.execute_on(stage, replica, input)
    }
}

/// One hedging run: warm up on the healthy chain, arm the straggler,
/// then measure per-batch latency on sequential submissions. Returns
/// (post-arming latencies ms, hedge stats).
fn hedged_run(
    hedge: Option<HedgeConfig>,
    inputs: &[Tensor],
    golden: &[Tensor],
) -> (Vec<f64>, amp4ec::pipeline::engine::HedgeStats) {
    let armed = Arc::new(AtomicBool::new(false));
    let engine = PersistentEngine::new(
        Arc::new(LaggyStages::bottleneck_pair(Arc::clone(&armed))),
        engine_cfg(hedge),
    )
    .expect("hedging engine");
    for i in 0..HEDGE_WARMUP_BATCHES {
        let run = engine.submit(&inputs[i]).expect("submit").wait().expect("warmup");
        assert_eq!(run.output, golden[i], "warmup output diverged");
    }
    armed.store(true, Ordering::SeqCst);
    let mut latencies = Vec::with_capacity(HEDGE_MEASURED_BATCHES);
    for i in 0..HEDGE_MEASURED_BATCHES {
        let j = HEDGE_WARMUP_BATCHES + i;
        let t0 = Instant::now();
        let run = engine.submit(&inputs[j]).expect("submit").wait().expect("batch");
        latencies.push(t0.elapsed().as_secs_f64() * 1e3);
        assert_eq!(run.output, golden[j], "straggler-era output diverged");
    }
    (latencies, engine.hedge_stats())
}

fn main() {
    let mut suite = BenchSuite::new("chaos");
    let inputs = batches();

    // ---- in-process reference -----------------------------------------
    let inproc_engine = PersistentEngine::new(
        Arc::new(SimStages::heterogeneous(SHARES, NOMINAL_MS)),
        engine_cfg(None),
    )
    .expect("inproc engine");
    let (inproc_out, inproc_wall_ms, inproc_sim_ms) =
        drive(&inproc_engine, &inputs);
    drop(inproc_engine);

    // ---- degeneracy pin: clean wire, no proxy, no deadline ------------
    let dir = std::env::temp_dir();
    let agents: Vec<_> = (0..SHARES.len())
        .map(|i| {
            let path = dir.join(format!(
                "amp4ec-bench-chaos-clean-{}-{i}.sock",
                std::process::id()
            ));
            NodeAgent::serve_uds(&path).expect("serve agent")
        })
        .collect();
    let addrs: Vec<_> = agents.iter().map(|a| a.addr().clone()).collect();
    let clean_engine = PersistentEngine::new(
        Arc::new(
            WireStages::connect_sim(
                &addrs,
                SHARES,
                NOMINAL_MS,
                Duration::from_secs(10),
            )
            .expect("connect clean"),
        ),
        engine_cfg(None),
    )
    .expect("clean wire engine");
    let (clean_out, clean_wall_ms, clean_sim_ms) =
        drive(&clean_engine, &inputs);
    drop(clean_engine);
    drop(agents);
    assert_eq!(
        clean_out, inproc_out,
        "degeneracy pin: clean wire must be bit-identical to in-process"
    );
    assert!(
        (clean_sim_ms - inproc_sim_ms).abs() < 1e-6,
        "degeneracy pin: sim accounting diverged ({clean_sim_ms} vs \
         {inproc_sim_ms})"
    );

    // ---- chaos gate: fragmentation + jitter on one stage's link -------
    let agents: Vec<_> = (0..SHARES.len())
        .map(|i| {
            let path = dir.join(format!(
                "amp4ec-bench-chaos-dirty-{}-{i}.sock",
                std::process::id()
            ));
            NodeAgent::serve_uds(&path).expect("serve agent")
        })
        .collect();
    let proxy = ChaosProxy::start_uds(
        dir.join(format!("amp4ec-bench-chaos-{}-proxy.sock", std::process::id())),
        agents[1].addr().clone(),
        vec![ConnPlans {
            to_upstream: FaultPlan::clean(0xBE)
                .with_fragmentation(8)
                .with_delays(0.25, 0.0, 1.5),
            to_client: FaultPlan::clean(0xEF)
                .with_fragmentation(8)
                .with_delays(0.25, 0.0, 1.5),
        }],
    )
    .expect("chaos proxy");
    let wired = vec![
        agents[0].addr().clone(),
        proxy.addr().clone(),
        agents[2].addr().clone(),
    ];
    let chaotic_wire = Arc::new(
        WireStages::connect_sim(
            &wired,
            SHARES,
            NOMINAL_MS,
            Duration::from_secs(10),
        )
        .expect("connect through chaos")
        .with_execute_timeout(Some(Duration::from_secs(5))),
    );
    let chaotic_engine =
        PersistentEngine::new(Arc::clone(&chaotic_wire), engine_cfg(None))
            .expect("chaotic wire engine");
    let (chaotic_out, chaotic_wall_ms, chaotic_sim_ms) =
        drive(&chaotic_engine, &inputs);
    drop(chaotic_engine);
    assert_eq!(
        chaotic_out, inproc_out,
        "chaos gate: benign chaos must not perturb a single output bit"
    );
    assert!(
        (chaotic_sim_ms - inproc_sim_ms).abs() < 1e-6,
        "chaos gate: sim accounting diverged ({chaotic_sim_ms} vs \
         {inproc_sim_ms})"
    );
    assert!(
        !chaotic_wire.any_dead(),
        "chaos gate: benign chaos must not kill a replica"
    );
    assert!(
        chaotic_wall_ms < CHAOS_WALL_BOUND_MS,
        "chaos gate: run took {chaotic_wall_ms:.0} ms (no-hang bound \
         {CHAOS_WALL_BOUND_MS:.0} ms)"
    );
    proxy.stop();
    drop(agents);
    let chaos_overhead_x = chaotic_wall_ms / clean_wall_ms;

    // ---- hedging gate: one straggler lane, p99 off vs on --------------
    let n_hedge = HEDGE_WARMUP_BATCHES + HEDGE_MEASURED_BATCHES;
    let hedge_inputs: Vec<Tensor> = (0..n_hedge)
        .map(|b| {
            let data = (0..4 * 4)
                .map(|i| (i as f32) * 0.125 - 1.0 + b as f32)
                .collect();
            Tensor::new(vec![4, 4], data).unwrap()
        })
        .collect();
    let reference = SimStages::heterogeneous(HEDGE_SHARES, NOMINAL_MS);
    let golden: Vec<Tensor> = hedge_inputs
        .iter()
        .map(|t| run_serial(&reference, t, 1).expect("serial").output)
        .collect();

    let (off_lat, off_stats) = hedged_run(None, &hedge_inputs, &golden);
    assert_eq!(off_stats.issued, 0, "hedging off must never issue");
    let (on_lat, on_stats) = hedged_run(
        Some(HedgeConfig { factor: 3.0, min_ms: 5.0, min_samples: 4 }),
        &hedge_inputs,
        &golden,
    );
    assert!(
        on_stats.issued > 0 && on_stats.wins > 0,
        "straggler lane must trigger winning hedges: {on_stats:?}"
    );

    let p99_off = percentile(&off_lat, 0.99);
    let p99_on = percentile(&on_lat, 0.99);
    let p50_off = percentile(&off_lat, 0.50);
    let p50_on = percentile(&on_lat, 0.50);
    assert!(
        p99_on <= HEDGE_P99_BOUND_X * p99_off,
        "hedging gate: p99 {p99_on:.1} ms vs off {p99_off:.1} ms exceeds \
         the {HEDGE_P99_BOUND_X}x bound"
    );

    suite.record_value("inproc wall", inproc_wall_ms, "ms");
    suite.record_value("clean wire wall", clean_wall_ms, "ms");
    suite.record_value("chaotic wire wall", chaotic_wall_ms, "ms");
    suite.record_value("chaos overhead", (chaos_overhead_x - 1.0) * 100.0, "%");
    suite.record_value("straggler p50 off", p50_off, "ms");
    suite.record_value("straggler p99 off", p99_off, "ms");
    suite.record_value("straggler p50 hedged", p50_on, "ms");
    suite.record_value("straggler p99 hedged", p99_on, "ms");
    suite.record_value("hedge p99 ratio", p99_on / p99_off, "x");
    suite.record_value("hedges issued", on_stats.issued as f64, "");
    suite.record_value("hedge wins", on_stats.wins as f64, "");
    suite.record_value("hedge wasted", on_stats.wasted as f64, "");

    let mut doc = BTreeMap::new();
    doc.insert("suite".into(), Json::Str("chaos".into()));
    doc.insert(
        "cpu_shares".into(),
        Json::Arr(SHARES.iter().map(|&s| Json::Num(s)).collect()),
    );
    doc.insert("nominal_ms".into(), Json::Num(NOMINAL_MS));
    doc.insert("rows_per_batch".into(), Json::from(ROWS_PER_BATCH));
    doc.insert("n_batches".into(), Json::from(N_BATCHES));
    doc.insert("depth".into(), Json::from(DEPTH));
    doc.insert("inproc_wall_ms".into(), Json::Num(inproc_wall_ms));
    doc.insert("clean_wall_ms".into(), Json::Num(clean_wall_ms));
    doc.insert("chaotic_wall_ms".into(), Json::Num(chaotic_wall_ms));
    doc.insert("chaos_overhead_x".into(), Json::Num(chaos_overhead_x));
    doc.insert("chaos_wall_bound_ms".into(), Json::Num(CHAOS_WALL_BOUND_MS));
    doc.insert(
        "straggler_lag_ms".into(),
        Json::from(STRAGGLER_LAG_MS as usize),
    );
    doc.insert("p50_off_ms".into(), Json::Num(p50_off));
    doc.insert("p99_off_ms".into(), Json::Num(p99_off));
    doc.insert("p50_hedged_ms".into(), Json::Num(p50_on));
    doc.insert("p99_hedged_ms".into(), Json::Num(p99_on));
    doc.insert("hedge_p99_ratio".into(), Json::Num(p99_on / p99_off));
    doc.insert("hedge_p99_bound_x".into(), Json::Num(HEDGE_P99_BOUND_X));
    doc.insert("hedges_issued".into(), Json::from(on_stats.issued as usize));
    doc.insert("hedge_wins".into(), Json::from(on_stats.wins as usize));
    doc.insert("hedge_wasted".into(), Json::from(on_stats.wasted as usize));
    std::fs::write("BENCH_chaos.json", Json::Obj(doc).to_string())
        .expect("write BENCH_chaos.json");
    println!("wrote BENCH_chaos.json");
}
