//! Wire-transport overhead: UDS loopback agents vs the in-process
//! chain on the wide-activation profile (4096 f32/row — the traffic
//! where frame encode/decode cost would show if it were going to).
//!
//! Both runs stream the same batches through a depth-4 persistent
//! engine over the paper's 1.0/0.6/0.4 heterogeneous profile; the wire
//! run hosts each stage in a `NodeAgent` behind a Unix domain socket.
//! Asserts the PR-6 acceptance criteria: outputs bit-identical to
//! in-process, and wall time within the stated bound
//! (`MAX_OVERHEAD_X`) of the in-process run — the sim sleeps dominate,
//! so the wire's per-micro-batch round-trips must stay in the noise.
//! Emits `BENCH_wire.json`. `cargo bench --bench wire`.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use amp4ec::metrics::wire as wire_metrics;
use amp4ec::pipeline::engine::{
    PersistentEngine, PersistentEngineConfig, SimStages,
};
use amp4ec::runtime::Tensor;
use amp4ec::transport::agent::NodeAgent;
use amp4ec::transport::WireStages;
use amp4ec::util::bench::BenchSuite;
use amp4ec::util::json::Json;

const SHARES: &[f64] = &[1.0, 0.6, 0.4];
const NOMINAL_MS: f64 = 1.0;
const COLS: usize = 4096;
const ROWS_PER_BATCH: usize = 6;
const N_BATCHES: usize = 12;
const DEPTH: usize = 4;
/// Stated acceptance bound: the UDS loopback run's wall time must stay
/// within this factor of the in-process run on the same workload.
const MAX_OVERHEAD_X: f64 = 1.5;

fn batches() -> Vec<Tensor> {
    (0..N_BATCHES)
        .map(|b| {
            let data = (0..ROWS_PER_BATCH * COLS)
                .map(|i| (i as f32) * 0.0625 - 2.0 + b as f32)
                .collect();
            Tensor::new(vec![ROWS_PER_BATCH, COLS], data).unwrap()
        })
        .collect()
}

fn engine_cfg() -> PersistentEngineConfig {
    PersistentEngineConfig {
        micro_batch_rows: 1,
        initial_depth: DEPTH,
        adaptive: None,
        ..Default::default()
    }
}

/// Stream every batch through `engine`; returns (outputs, wall ms,
/// final sim makespan).
fn drive(engine: &PersistentEngine, inputs: &[Tensor]) -> (Vec<Tensor>, f64, f64) {
    let t0 = Instant::now();
    let handles: Vec<_> = inputs
        .iter()
        .map(|b| engine.submit(b).expect("submit"))
        .collect();
    let outputs: Vec<Tensor> = handles
        .into_iter()
        .map(|h| h.wait().expect("batch").output)
        .collect();
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    (outputs, wall_ms, engine.makespan_ms())
}

fn main() {
    let mut suite = BenchSuite::new("wire");
    let inputs = batches();
    let total_rows = (N_BATCHES * ROWS_PER_BATCH) as f64;

    // ---- in-process reference -----------------------------------------
    let inproc_engine = PersistentEngine::new(
        Arc::new(SimStages::heterogeneous(SHARES, NOMINAL_MS)),
        engine_cfg(),
    )
    .expect("inproc engine");
    let (inproc_out, inproc_wall_ms, inproc_sim_ms) =
        drive(&inproc_engine, &inputs);
    drop(inproc_engine);

    // ---- UDS loopback: one agent per stage ----------------------------
    let dir = std::env::temp_dir();
    let agents: Vec<_> = (0..SHARES.len())
        .map(|i| {
            let path = dir
                .join(format!("amp4ec-bench-wire-{}-{i}.sock", std::process::id()));
            NodeAgent::serve_uds(&path).expect("serve agent")
        })
        .collect();
    let addrs: Vec<_> = agents.iter().map(|a| a.addr().clone()).collect();

    let wire_before = wire_metrics::snapshot();
    let wire_engine = PersistentEngine::new(
        Arc::new(
            WireStages::connect_sim(
                &addrs,
                SHARES,
                NOMINAL_MS,
                Duration::from_secs(10),
            )
            .expect("connect agents"),
        ),
        engine_cfg(),
    )
    .expect("wire engine");
    let (wire_out, uds_wall_ms, uds_sim_ms) = drive(&wire_engine, &inputs);
    drop(wire_engine);
    let moved = wire_metrics::snapshot().since(&wire_before);
    drop(agents);

    // ---- acceptance: bit-identity and bounded overhead ----------------
    assert_eq!(
        wire_out, inproc_out,
        "wire outputs must be bit-identical to in-process"
    );
    assert!(
        (uds_sim_ms - inproc_sim_ms).abs() < 1e-6,
        "sim accounting diverged: wire {uds_sim_ms:.3} ms vs in-process \
         {inproc_sim_ms:.3} ms"
    );
    let overhead_x = uds_wall_ms / inproc_wall_ms;
    assert!(
        overhead_x <= MAX_OVERHEAD_X,
        "UDS loopback wall {uds_wall_ms:.1} ms is {overhead_x:.2}x the \
         in-process {inproc_wall_ms:.1} ms (bound {MAX_OVERHEAD_X}x)"
    );
    assert!(
        moved.frames_tx > 0 && moved.frames_rx > 0,
        "wire counters never moved: {moved:?}"
    );

    suite.record_value("inproc wall", inproc_wall_ms, "ms");
    suite.record_value("uds wall", uds_wall_ms, "ms");
    suite.record_value("uds overhead", (overhead_x - 1.0) * 100.0, "%");
    suite.record_value(
        "inproc throughput",
        total_rows / (inproc_wall_ms / 1e3),
        "rows/s",
    );
    suite.record_value(
        "uds throughput",
        total_rows / (uds_wall_ms / 1e3),
        "rows/s",
    );
    suite.record_value("wire frames tx", moved.frames_tx as f64, "");
    suite.record_value(
        "wire MB tx",
        moved.bytes_tx as f64 / (1024.0 * 1024.0),
        "MB",
    );
    suite.record_value(
        "encode per frame",
        moved.encode_ns as f64 / 1e3 / moved.frames_tx.max(1) as f64,
        "us",
    );

    let mut doc = BTreeMap::new();
    doc.insert("suite".into(), Json::Str("wire".into()));
    doc.insert(
        "cpu_shares".into(),
        Json::Arr(SHARES.iter().map(|&s| Json::Num(s)).collect()),
    );
    doc.insert("nominal_ms".into(), Json::Num(NOMINAL_MS));
    doc.insert("row_len".into(), Json::from(COLS));
    doc.insert("rows_per_batch".into(), Json::from(ROWS_PER_BATCH));
    doc.insert("n_batches".into(), Json::from(N_BATCHES));
    doc.insert("depth".into(), Json::from(DEPTH));
    doc.insert("inproc_wall_ms".into(), Json::Num(inproc_wall_ms));
    doc.insert("uds_wall_ms".into(), Json::Num(uds_wall_ms));
    doc.insert("inproc_sim_ms".into(), Json::Num(inproc_sim_ms));
    doc.insert("uds_sim_ms".into(), Json::Num(uds_sim_ms));
    doc.insert("overhead_x".into(), Json::Num(overhead_x));
    doc.insert("bound_x".into(), Json::Num(MAX_OVERHEAD_X));
    doc.insert(
        "inproc_rows_per_s".into(),
        Json::Num(total_rows / (inproc_wall_ms / 1e3)),
    );
    doc.insert(
        "uds_rows_per_s".into(),
        Json::Num(total_rows / (uds_wall_ms / 1e3)),
    );
    doc.insert("frames_tx".into(), Json::from(moved.frames_tx as usize));
    doc.insert("frames_rx".into(), Json::from(moved.frames_rx as usize));
    doc.insert("bytes_tx".into(), Json::from(moved.bytes_tx as usize));
    doc.insert("bytes_rx".into(), Json::from(moved.bytes_rx as usize));
    doc.insert("encode_ns".into(), Json::from(moved.encode_ns as usize));
    doc.insert("decode_ns".into(), Json::from(moved.decode_ns as usize));
    doc.insert(
        "encode_us_per_frame".into(),
        Json::Num(moved.encode_ns as f64 / 1e3 / moved.frames_tx.max(1) as f64),
    );
    std::fs::write("BENCH_wire.json", Json::Obj(doc).to_string())
        .expect("write BENCH_wire.json");
    println!("wrote BENCH_wire.json");
}
