//! Self-healing churn gate (ISSUE 8): serving under a seeded node-kill
//! schedule. `cargo bench --bench churn`.
//!
//! A skewed 3-stage chain (1.0 / 0.25 / 1.0 CPU shares) with the
//! bottleneck replicated 2 ways streams 24 batches through the
//! persistent engine while a kill schedule takes one bottleneck replica
//! down mid-run (it serves a fixed number of micro-batches, then dies
//! with work in flight — the sim twin of a node dropping off the
//! network). Three configurations:
//!
//! - **clean**: no kill — the latency/makespan baseline.
//! - **heal**: kill with replay on — the driver re-runs the dead
//!   replica's in-flight micro-batches on the survivor. Gates: every
//!   handle resolves, zero failed batches, all outputs bit-identical to
//!   the serial schedule, >= 1 replay, p99 and makespan degradation
//!   bounded (the lost replica halves the bottleneck fan-out, so ~2x is
//!   physics; the gates allow slack on top, not hangs or failures).
//! - **fail-fast**: the same schedule with replay off — pins today's
//!   behaviour: the doomed batch fails, everything else resolves.
//!
//! Emits `BENCH_churn.json`.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use amp4ec::metrics::markdown_table;
use amp4ec::pipeline::engine::{
    run_serial, PersistentEngine, PersistentEngineConfig, SimStages,
    StageExec,
};
use amp4ec::runtime::Tensor;
use amp4ec::util::bench::BenchSuite;
use amp4ec::util::json::Json;

/// Kill schedule over one target replica: serve `fuse` micro-batches,
/// then fail every execute routed to it (the node is gone). Mirrors the
/// test harness's kill switch, inlined here because benches cannot link
/// the test-only crate.
struct KillSchedule {
    inner: SimStages,
    stage: usize,
    replica: usize,
    dead: AtomicBool,
    /// Executes remaining before the kill (`usize::MAX` = never).
    fuse: AtomicUsize,
}

impl KillSchedule {
    fn new(inner: SimStages, stage: usize, replica: usize, fuse: usize) -> KillSchedule {
        KillSchedule {
            inner,
            stage,
            replica,
            dead: AtomicBool::new(false),
            fuse: AtomicUsize::new(fuse),
        }
    }

    fn gate(&self, stage: usize, replica: usize) -> anyhow::Result<()> {
        if stage != self.stage || replica != self.replica {
            return Ok(());
        }
        if self.dead.load(Ordering::SeqCst) {
            anyhow::bail!("stage {stage} replica {replica} node is gone");
        }
        let armed = self.fuse.fetch_update(Ordering::SeqCst, Ordering::SeqCst, |n| {
            (n != usize::MAX).then(|| n.saturating_sub(1))
        });
        if armed == Ok(0) {
            self.dead.store(true, Ordering::SeqCst);
            anyhow::bail!("stage {stage} replica {replica} node died mid-stream");
        }
        Ok(())
    }
}

impl StageExec for KillSchedule {
    fn num_stages(&self) -> usize {
        self.inner.num_stages()
    }
    fn node_id(&self, stage: usize) -> usize {
        self.inner.node_id(stage)
    }
    fn backlog(&self, stage: usize) -> usize {
        self.inner.backlog(stage)
    }
    fn comm_in(&self, stage: usize, bytes: u64) -> f64 {
        self.inner.comm_in(stage, bytes)
    }
    fn comm_out(&self, bytes: u64) -> f64 {
        self.inner.comm_out(bytes)
    }
    fn replicas(&self, stage: usize) -> usize {
        self.inner.replicas(stage)
    }
    fn replica_node_id(&self, stage: usize, replica: usize) -> usize {
        self.inner.replica_node_id(stage, replica)
    }
    fn replica_alive(&self, stage: usize, replica: usize) -> bool {
        !(stage == self.stage
            && replica == self.replica
            && self.dead.load(Ordering::SeqCst))
            && self.inner.replica_alive(stage, replica)
    }
    fn comm_in_on(&self, stage: usize, replica: usize, bytes: u64) -> f64 {
        self.inner.comm_in_on(stage, replica, bytes)
    }
    fn execute(&self, stage: usize, input: Tensor) -> anyhow::Result<(Tensor, f64)> {
        self.gate(stage, 0)?;
        self.inner.execute(stage, input)
    }
    fn execute_on(
        &self,
        stage: usize,
        replica: usize,
        input: Tensor,
    ) -> anyhow::Result<(Tensor, f64)> {
        self.gate(stage, replica)?;
        self.inner.execute_on(stage, replica, input)
    }
}

fn input_off(rows: usize, cols: usize, off: f32) -> Tensor {
    let data = (0..rows * cols)
        .map(|i| (i as f32) * 0.125 - 4.0 + off)
        .collect();
    Tensor::new(vec![rows, cols], data).unwrap()
}

fn p99(lat_ms: &[f64]) -> f64 {
    let mut sorted = lat_ms.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((sorted.len() as f64 * 0.99).ceil() as usize)
        .saturating_sub(1)
        .min(sorted.len() - 1);
    sorted[idx]
}

struct RunResult {
    makespan_ms: f64,
    p99_ms: f64,
    completed: usize,
    failed: usize,
    replays_attempted: u64,
    replays_succeeded: u64,
}

/// Stream `batches` through one engine; kill schedule optional. Every
/// handle is waited on — a hang here hangs the bench, which IS the
/// zero-hung-handles gate.
fn run_config(
    shares: &[f64],
    batches: &[Tensor],
    goldens: &[Tensor],
    schedule: Option<usize>,
    replay: bool,
) -> RunResult {
    let sim = SimStages::with_replicas(shares, 1.0, &[1, 2, 1]);
    let stages = KillSchedule::new(sim, 1, 1, schedule.unwrap_or(usize::MAX));
    let engine = PersistentEngine::new(
        Arc::new(stages),
        PersistentEngineConfig {
            micro_batch_rows: 1,
            initial_depth: 12,
            adaptive: None,
            replay,
            ..Default::default()
        },
    )
    .expect("churn engine");

    let submits: Vec<(Instant, _)> = batches
        .iter()
        .map(|b| (Instant::now(), engine.submit(b).expect("submit")))
        .collect();
    let mut lat_ms = Vec::new();
    let mut completed = 0usize;
    let mut failed = 0usize;
    for ((t0, handle), want) in submits.into_iter().zip(goldens) {
        match handle.wait() {
            Ok(run) => {
                lat_ms.push(t0.elapsed().as_secs_f64() * 1e3);
                assert_eq!(
                    &run.output, want,
                    "non-shed output diverged from the serial schedule"
                );
                completed += 1;
            }
            Err(_) => failed += 1,
        }
    }
    let replays = engine.replay_stats();
    RunResult {
        makespan_ms: engine.makespan_ms(),
        p99_ms: if lat_ms.is_empty() { 0.0 } else { p99(&lat_ms) },
        completed,
        failed,
        replays_attempted: replays.attempted,
        replays_succeeded: replays.succeeded,
    }
}

fn main() {
    let mut suite = BenchSuite::new("churn");

    let shares = [1.0f64, 0.25, 1.0];
    let n_batches = 24usize;
    let rows_per_batch = 8usize;
    // The seeded kill schedule: the doomed replica serves 30 of its
    // ~96 micro-batches, then dies with work in flight (~batch 8 of 24).
    let kill_after = 30usize;

    let batches: Vec<Tensor> = (0..n_batches)
        .map(|i| input_off(rows_per_batch, 32, i as f32))
        .collect();
    let serial = SimStages::heterogeneous(&shares, 1.0);
    let goldens: Vec<Tensor> = batches
        .iter()
        .map(|b| run_serial(&serial, b, 1).expect("serial").output)
        .collect();

    let clean = run_config(&shares, &batches, &goldens, None, true);
    let heal = run_config(&shares, &batches, &goldens, Some(kill_after), true);
    let fail_fast =
        run_config(&shares, &batches, &goldens, Some(kill_after), false);

    let p99_ratio = heal.p99_ms / clean.p99_ms.max(1e-9);
    let makespan_ratio = heal.makespan_ms / clean.makespan_ms.max(1e-9);

    println!(
        "{}",
        markdown_table(
            "Serving under the seeded node-kill schedule (24 batches, k=2 bottleneck)",
            &["Config", "Completed", "Failed", "Makespan ms", "p99 ms", "Replays"],
            &[
                vec![
                    "clean".into(),
                    format!("{}", clean.completed),
                    format!("{}", clean.failed),
                    format!("{:.1}", clean.makespan_ms),
                    format!("{:.1}", clean.p99_ms),
                    "0".into(),
                ],
                vec![
                    "heal (replay on)".into(),
                    format!("{}", heal.completed),
                    format!("{}", heal.failed),
                    format!("{:.1}", heal.makespan_ms),
                    format!("{:.1}", heal.p99_ms),
                    format!("{}/{}", heal.replays_succeeded, heal.replays_attempted),
                ],
                vec![
                    "fail-fast (replay off)".into(),
                    format!("{}", fail_fast.completed),
                    format!("{}", fail_fast.failed),
                    format!("{:.1}", fail_fast.makespan_ms),
                    format!("{:.1}", fail_fast.p99_ms),
                    "0".into(),
                ],
            ],
        )
    );

    suite.record_value("clean p99", clean.p99_ms, "ms");
    suite.record_value("heal p99", heal.p99_ms, "ms");
    suite.record_value("p99 degradation", p99_ratio, "x");
    suite.record_value("makespan degradation", makespan_ratio, "x");
    suite.record_value(
        "replays succeeded",
        heal.replays_succeeded as f64,
        "batches",
    );

    // --- The ISSUE-8 churn gates. -----------------------------------
    // Healing on: the kill is invisible to callers. Every handle
    // resolved (the waits above returned), nothing failed, outputs were
    // bit-identical (asserted per batch), and the recovery actually
    // exercised the replay path.
    assert_eq!(clean.completed, n_batches, "clean run must complete");
    assert_eq!(clean.failed, 0);
    assert_eq!(
        heal.completed, n_batches,
        "healed run dropped batches ({} failed)",
        heal.failed
    );
    assert_eq!(heal.failed, 0, "healed run must not surface the kill");
    assert!(
        heal.replays_succeeded >= 1,
        "kill schedule guarantees at least one replay"
    );
    // Losing one of two bottleneck replicas halves the fan-out: ~2x
    // degradation is physics. Gate with slack — bounded, not unbounded.
    assert!(
        makespan_ratio <= 3.0,
        "makespan degraded {makespan_ratio:.2}x (> 3x bound)"
    );
    assert!(
        p99_ratio <= 4.0,
        "p99 degraded {p99_ratio:.2}x (> 4x bound)"
    );
    // Healing off: the same schedule reproduces today's fail-fast
    // behaviour — the doomed batch errors, the rest still resolve.
    assert!(
        fail_fast.failed >= 1,
        "fail-fast pin: the kill must surface with replay off"
    );
    assert_eq!(
        fail_fast.completed + fail_fast.failed,
        n_batches,
        "fail-fast run hung handles"
    );
    assert_eq!(fail_fast.replays_attempted, 0, "replay must stay opt-in");

    let run_json = |r: &RunResult| {
        let mut j = BTreeMap::new();
        j.insert("completed".into(), Json::from(r.completed));
        j.insert("failed".into(), Json::from(r.failed));
        j.insert("makespan_ms".into(), Json::Num(r.makespan_ms));
        j.insert("p99_ms".into(), Json::Num(r.p99_ms));
        j.insert(
            "replays_attempted".into(),
            Json::from(r.replays_attempted as usize),
        );
        j.insert(
            "replays_succeeded".into(),
            Json::from(r.replays_succeeded as usize),
        );
        Json::Obj(j)
    };
    let mut doc = BTreeMap::new();
    doc.insert("suite".into(), Json::Str("churn".into()));
    doc.insert(
        "cpu_shares".into(),
        Json::Arr(shares.iter().map(|&s| Json::Num(s)).collect()),
    );
    doc.insert("n_batches".into(), Json::from(n_batches));
    doc.insert("rows_per_batch".into(), Json::from(rows_per_batch));
    doc.insert("kill_after_micro_batches".into(), Json::from(kill_after));
    doc.insert("clean".into(), run_json(&clean));
    doc.insert("heal".into(), run_json(&heal));
    doc.insert("fail_fast".into(), run_json(&fail_fast));
    doc.insert("p99_degradation".into(), Json::Num(p99_ratio));
    doc.insert("makespan_degradation".into(), Json::Num(makespan_ratio));
    doc.insert("bit_identical".into(), Json::Bool(true));
    doc.insert("hung_handles".into(), Json::from(0usize));
    std::fs::write("BENCH_churn.json", Json::Obj(doc).to_string())
        .expect("write BENCH_churn.json");
    println!("wrote BENCH_churn.json");
}
