//! Runtime/L1/L2 performance evidence: per-block execution cost, the
//! monolithic-vs-chained overhead, batch efficiency, and upload costs.
//! This is the measurement base for the EXPERIMENTS.md §Perf log.
//! `cargo bench --bench runtime`.

use amp4ec::manifest::Manifest;
use amp4ec::runtime::{Tensor, XlaRuntime};
use amp4ec::util::bench::BenchSuite;
use amp4ec::util::rng::Rng;

fn rand_tensor(shape: Vec<usize>, seed: u64) -> Tensor {
    let mut t = Tensor::zeros(shape);
    Rng::new(seed).fill_normal_f32(t.data_mut());
    t
}

fn main() {
    let m = Manifest::load(&amp4ec::artifacts_dir())
        .expect("run `make artifacts` first");
    let rt = XlaRuntime::cpu().unwrap();
    let mut suite = BenchSuite::new("runtime");

    // ---- monolithic batch sweep ----------------------------------------
    let mono = m.monolithic.as_ref().unwrap();
    let w_full = Tensor::from_f32_file(
        &m.dir.join(&mono.weights_file),
        vec![m.total_params as usize],
    )
    .unwrap();
    let wbuf = rt.upload(&w_full).unwrap();
    let mut per_image = Vec::new();
    for &batch in &m.batch_sizes {
        let exe = rt.load_hlo(&m.dir.join(&mono.artifacts[&batch])).unwrap();
        let x = rand_tensor(vec![batch, m.input_hw, m.input_hw, m.input_channels], 7);
        let r = suite.bench(&format!("monolithic forward b{batch}"), 2, 8, || {
            let xb = rt.upload(&x).unwrap();
            std::hint::black_box(
                exe.run_with_weights(&wbuf, &xb, &[batch, m.num_classes]).unwrap(),
            );
        });
        per_image.push((batch, r.mean_ms / batch as f64));
        suite.record_value(
            &format!("monolithic per-image cost b{batch}"),
            r.mean_ms / batch as f64,
            "ms/image",
        );
    }
    // Batching amortizes per-request overheads (upload, dispatch, comm,
    // batching window); kernel time itself is roughly linear in batch on
    // this single-core host, so only require that b8 is not
    // catastrophically worse per image.
    if per_image.len() >= 2 {
        let (b1, b8) = (per_image[0].1, per_image[1].1);
        suite.record_value("batch-8 per-image ratio", b8 / b1, "x");
        assert!(b8 / b1 < 3.0, "batch-8 pathologically slow: {b1} vs {b8}");
    }

    // ---- per-block costs (batch 1) --------------------------------------
    // The three heaviest + three representative blocks.
    let picks = [0usize, 1, 7, 14, 18, 19];
    let mut act = rand_tensor(
        vec![1, m.input_hw, m.input_hw, m.input_channels],
        9,
    );
    let mut block_ms = vec![0.0f64; m.blocks.len()];
    for b in &m.blocks {
        let exe = rt.load_hlo(&m.artifact_path(b, 1).unwrap()).unwrap();
        let w = Tensor::from_f32_file(&m.weights_path(b), vec![b.param_count as usize])
            .unwrap();
        let wb = rt.upload(&w).unwrap();
        let out_shape = if b.name == "classifier" {
            vec![1, m.num_classes]
        } else {
            vec![1, b.out_shape[0], b.out_shape[1], b.out_shape[2]]
        };
        // Time it (lightweight: 4 iters, it's 20 blocks).
        let t0 = std::time::Instant::now();
        let iters = 4;
        let mut out = act.clone();
        for _ in 0..iters {
            let ab = rt.upload(&act).unwrap();
            out = exe.run_with_weights(&wb, &ab, &out_shape).unwrap();
        }
        let ms = t0.elapsed().as_secs_f64() * 1e3 / iters as f64;
        block_ms[b.index] = ms;
        if picks.contains(&b.index) {
            suite.record_value(&format!("block {:02} {}", b.index, b.name), ms, "ms");
        }
        act = out;
    }
    let chain_total: f64 = block_ms.iter().sum();
    suite.record_value("sum of per-block costs b1", chain_total, "ms");
    suite.record_value(
        "chaining overhead vs monolithic b1",
        chain_total / per_image[0].1,
        "x",
    );

    // ---- upload cost -----------------------------------------------------
    let x1 = rand_tensor(vec![1, m.input_hw, m.input_hw, m.input_channels], 11);
    suite.bench("host->device upload 108KB activation", 10, 100, || {
        std::hint::black_box(rt.upload(&x1).unwrap());
    });
    println!("\nper-block cost profile (ms at b1): {:?}",
             block_ms.iter().map(|v| format!("{v:.1}")).collect::<Vec<_>>());
}
