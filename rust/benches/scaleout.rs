//! Stage replication scale-out gate (ISSUE 7).
//!
//! A skewed 3-stage chain (1.0 / 0.25 / 1.0 CPU shares, so the middle
//! stage is the 4x bottleneck) served by the persistent engine with the
//! bottleneck replicated k ∈ {1, 2, 4} ways, each replica on its own
//! fresh virtual node. The pipeline bound is the slowest *effective*
//! stage time — max(1, 4/k, 1) ms per micro-batch — so serving
//! throughput must scale near-linearly in k until the fan-out stops
//! being the bottleneck. Acceptance gates: >= 1.7x at k=2 and >= 3x at
//! k=4 over the k=1 chain, with every configuration's output
//! bit-identical to the serial schedule (replication is a scheduling
//! change, never a numerics change). Emits `BENCH_scaleout.json`.
//! `cargo bench --bench scaleout`.

use std::collections::BTreeMap;
use std::sync::Arc;

use amp4ec::metrics::markdown_table;
use amp4ec::pipeline::engine::{
    run_serial, PersistentEngine, PersistentEngineConfig, SimStages,
};
use amp4ec::runtime::Tensor;
use amp4ec::util::bench::BenchSuite;
use amp4ec::util::json::Json;

fn input_off(rows: usize, cols: usize, off: f32) -> Tensor {
    let data = (0..rows * cols)
        .map(|i| (i as f32) * 0.125 - 4.0 + off)
        .collect();
    Tensor::new(vec![rows, cols], data).unwrap()
}

fn main() {
    let mut suite = BenchSuite::new("scaleout");

    // Skewed bottleneck profile: stage 1 runs at a quarter of the CPU
    // share, so 1 ms nominal becomes 1 / 4 / 1 ms across the chain.
    let shares = [1.0, 0.25, 1.0];
    let nominal_ms = 1.0;
    let n_batches = 8usize;
    let rows_per_batch = 8usize;
    let batches: Vec<Tensor> = (0..n_batches)
        .map(|i| input_off(rows_per_batch, 32, i as f32))
        .collect();
    let total_rows = (n_batches * rows_per_batch) as f64;

    // Golden outputs: the serial schedule on the unreplicated chain.
    let serial_stages = SimStages::heterogeneous(&shares, nominal_ms);
    let serial_outputs: Vec<Tensor> = batches
        .iter()
        .map(|b| run_serial(&serial_stages, b, 1).expect("serial").output)
        .collect();

    let mut table_rows = Vec::new();
    let mut json_configs = Vec::new();
    let mut speedup_at = BTreeMap::new();
    let mut k1_ms = 0.0f64;
    for k in [1usize, 2, 4] {
        let reps = vec![1, k, 1];
        let engine = PersistentEngine::new(
            Arc::new(SimStages::with_replicas(&shares, nominal_ms, &reps)),
            PersistentEngineConfig {
                micro_batch_rows: 1,
                initial_depth: 12,
                adaptive: None,
                ..Default::default()
            },
        )
        .expect("scale-out engine");
        let replica_map = engine.replica_nodes().to_vec();
        assert_eq!(replica_map[1].len(), k, "bottleneck replica count");

        // Back-to-back batches through one long-lived engine: the
        // cross-batch stream is what replication must speed up.
        let handles: Vec<_> = batches
            .iter()
            .map(|b| engine.submit(b).expect("submit"))
            .collect();
        for (h, want) in handles.into_iter().zip(&serial_outputs) {
            let run = h.wait().expect("scale-out run");
            // The ISSUE-7 bit-identity gate: every fan-out degree
            // reassembles the serial rows exactly.
            assert_eq!(
                &run.output, want,
                "k={k} output diverged from serial"
            );
        }
        let sim_ms = engine.makespan_ms();
        if k == 1 {
            k1_ms = sim_ms;
        }
        let speedup = k1_ms / sim_ms;
        speedup_at.insert(k, speedup);
        let throughput = total_rows / (sim_ms / 1e3);

        let counters = engine.replica_counters();
        let lanes: Vec<_> =
            counters.iter().filter(|c| c.stage == 1).collect();
        assert_eq!(lanes.len(), k, "one counter per bottleneck lane");
        for lane in &lanes {
            assert!(
                lane.micro_batches > 0,
                "bottleneck lane {} idle at k={k}",
                lane.replica
            );
        }

        table_rows.push(vec![
            format!("{k}"),
            format!("{sim_ms:.1}"),
            format!("{throughput:.0}"),
            format!("{speedup:.2}x"),
            format!(
                "{:?}",
                lanes.iter().map(|c| c.micro_batches).collect::<Vec<_>>()
            ),
        ]);
        suite.record_value(
            &format!("throughput k={k}"),
            throughput,
            "rows/s",
        );
        suite.record_value(&format!("speedup k={k}"), speedup, "x");

        let mut cfg = BTreeMap::new();
        cfg.insert("replicas".into(), Json::from(k));
        cfg.insert("sim_ms".into(), Json::Num(sim_ms));
        cfg.insert("rows_per_s".into(), Json::Num(throughput));
        cfg.insert("speedup_vs_k1".into(), Json::Num(speedup));
        cfg.insert(
            "replica_map".into(),
            Json::Arr(
                replica_map
                    .iter()
                    .map(|nodes| {
                        Json::Arr(
                            nodes.iter().map(|&n| Json::from(n)).collect(),
                        )
                    })
                    .collect(),
            ),
        );
        cfg.insert(
            "per_replica".into(),
            Json::Arr(
                counters
                    .iter()
                    .map(|c| {
                        let mut j = BTreeMap::new();
                        j.insert("stage".into(), Json::from(c.stage));
                        j.insert("replica".into(), Json::from(c.replica));
                        j.insert("node".into(), Json::from(c.node));
                        j.insert(
                            "occupancy_pct".into(),
                            Json::Num(100.0 * c.occupancy(sim_ms)),
                        );
                        j.insert("bubble_ms".into(), Json::Num(c.bubble_ms));
                        j.insert(
                            "micro_batches".into(),
                            Json::from(c.micro_batches as usize),
                        );
                        Json::Obj(j)
                    })
                    .collect(),
            ),
        );
        json_configs.push(Json::Obj(cfg));
    }

    println!(
        "{}",
        markdown_table(
            "Replica scale-out on the skewed bottleneck (64 rows, depth 12)",
            &[
                "Replicas (stage 1)",
                "Sim total ms",
                "Rows/s",
                "Speedup vs k=1",
                "Lane micro-batches",
            ],
            &table_rows,
        )
    );

    // The ISSUE-7 near-linear scaling gates.
    let s2 = speedup_at[&2];
    let s4 = speedup_at[&4];
    assert!(
        s2 >= 1.7,
        "k=2 speedup {s2:.2}x below the 1.7x scale-out gate"
    );
    assert!(
        s4 >= 3.0,
        "k=4 speedup {s4:.2}x below the 3x scale-out gate"
    );

    let mut doc = BTreeMap::new();
    doc.insert("suite".into(), Json::Str("scaleout".into()));
    doc.insert(
        "cpu_shares".into(),
        Json::Arr(shares.iter().map(|&s| Json::Num(s)).collect()),
    );
    doc.insert("nominal_ms".into(), Json::Num(nominal_ms));
    doc.insert("n_batches".into(), Json::from(n_batches));
    doc.insert("rows_per_batch".into(), Json::from(rows_per_batch));
    doc.insert("depth".into(), Json::from(12usize));
    doc.insert("configs".into(), Json::Arr(json_configs));
    doc.insert("speedup_k2".into(), Json::Num(s2));
    doc.insert("speedup_k4".into(), Json::Num(s4));
    doc.insert("bit_identical".into(), Json::Bool(true));
    std::fs::write("BENCH_scaleout.json", Json::Obj(doc).to_string())
        .expect("write BENCH_scaleout.json");
    println!("wrote BENCH_scaleout.json");
}
