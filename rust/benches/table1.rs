//! Table I regeneration: comparison of system performance metrics between
//! AMP4EC(+Cache) and the monolithic approach.
//!
//! Paper's setup (§IV-B): MobileNetV2, batches of 32 inference requests;
//! monolithic on one 2-core/2GB container; distributed over a
//! heterogeneous cluster (1.0/1GB, 0.6/512MB, 0.4/512MB). We reproduce
//! the *shape* (who wins, roughly what factor); absolute numbers differ —
//! our substrate is a virtual cluster over XLA CPU, not Docker-on-MacOS
//! over PyTorch. Run with `cargo bench --bench table1`.

use std::sync::Arc;

use amp4ec::baseline::{baseline_node_spec, MonolithicService};
use amp4ec::cluster::{Cluster, SimParams};
use amp4ec::config::AmpConfig;
use amp4ec::manifest::Manifest;
use amp4ec::metrics::{markdown_table, RunMetrics};
use amp4ec::server::EdgeServer;
use amp4ec::serving::{IngressConfig, ServiceHandle};
use amp4ec::workload::{feed, Arrival, InputPool};

const REQUESTS: usize = 32;
const DISTINCT: usize = 8;

struct Row {
    name: &'static str,
    metrics: RunMetrics,
    deploy_bytes: u64,
    monitor_pct: f64,
}

fn run_monolithic(manifest: &Manifest) -> Row {
    let cluster = Cluster::new(SimParams::default());
    let id = cluster.add_node(baseline_node_spec());
    let svc = Arc::new(
        MonolithicService::new(manifest, cluster.get(id).unwrap(), 1).unwrap(),
    );
    let deploy_bytes = manifest.monolithic.as_ref().unwrap().weights_bytes;
    let pool = InputPool::new(svc.input_shape(), DISTINCT, 101);
    // Same unified serving ingress the distributed configurations use.
    let handle = ServiceHandle::new(svc, IngressConfig::default(), None);
    feed(&handle, &pool, REQUESTS, Arrival::Closed, 102);
    Row {
        name: "Monolithic",
        metrics: handle.finish(),
        deploy_bytes,
        monitor_pct: 0.0,
    }
}

fn run_amp4ec(name: &'static str, cached: bool) -> Row {
    let mut cfg = if cached {
        AmpConfig::paper_cluster_cached(&amp4ec::artifacts_dir())
    } else {
        AmpConfig::paper_cluster(&amp4ec::artifacts_dir())
    };
    cfg.batch = 8;
    cfg.profiled_partitioning = true;
    let server = EdgeServer::start(cfg).unwrap();
    if cached {
        // Warm half the pool; the measured run mixes hits and misses
        // (the paper's cache was partially effective, not omniscient).
        server
            .serve_workload(DISTINCT, DISTINCT, Arrival::Closed, 101)
            .unwrap();
    }
    let pool_size = if cached { DISTINCT * 2 } else { DISTINCT };
    let report = server
        .serve_workload(REQUESTS, pool_size, Arrival::Closed, 101)
        .unwrap();
    Row {
        name,
        metrics: report.metrics,
        deploy_bytes: report.deploy_transfer_bytes,
        monitor_pct: report.monitor_overhead_pct,
    }
}

fn main() {
    let manifest = Manifest::load(&amp4ec::artifacts_dir())
        .expect("run `make artifacts` first");
    eprintln!("table1: running 3 configurations x {REQUESTS} requests...");

    let rows = vec![
        run_amp4ec("AMP4EC+Cache", true),
        run_amp4ec("AMP4EC", false),
        run_monolithic(&manifest),
    ];

    let fmt_rows: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            let m = &r.metrics;
            vec![
                r.name.to_string(),
                format!("{:.2}", m.mean_latency_ms()),
                format!("{:.2}", m.throughput_rps()),
                format!("{:.2}", m.mean_comm_ms()),
                format!("{:.2}", m.mean_sched_ms()),
                format!("{:.3}", m.stability_score()),
                format!("{:.1}", r.deploy_bytes as f64 / 1e6),
                format!("{:.3}", r.monitor_pct),
                format!("{}", m.cache_hits),
            ]
        })
        .collect();
    println!(
        "{}",
        markdown_table(
            "Table I — AMP4EC vs monolithic (paper: -78% latency, +415% throughput)",
            &[
                "Config", "Latency (ms)", "Throughput (req/s)",
                "Comm overhead (ms)", "Sched overhead (ms)", "Stability",
                "Bandwidth (MB)", "Monitor CPU %", "Cache hits"
            ],
            &fmt_rows,
        )
    );

    let mono = &rows[2].metrics;
    let cache = &rows[0].metrics;
    let plain = &rows[1].metrics;
    println!("improvements vs monolithic:");
    println!(
        "  AMP4EC       : latency {:+.1}%  throughput {:+.1}%",
        (plain.mean_latency_ms() / mono.mean_latency_ms() - 1.0) * 100.0,
        (plain.throughput_rps() / mono.throughput_rps() - 1.0) * 100.0
    );
    println!(
        "  AMP4EC+Cache : latency {:+.1}%  throughput {:+.1}%",
        (cache.mean_latency_ms() / mono.mean_latency_ms() - 1.0) * 100.0,
        (cache.throughput_rps() / mono.throughput_rps() - 1.0) * 100.0
    );
    println!(
        "  paper        : latency -78.35%  throughput +414.73%  (shape target)"
    );

    // Shape assertions — fail loudly if the reproduction regresses.
    // Plain AMP4EC ties an *optimized* monolithic baseline (equal
    // aggregate compute; the paper's 5x gap reflects its unoptimized
    // baseline — see EXPERIMENTS.md); +Cache must beat it outright.
    assert!(
        plain.throughput_rps() > mono.throughput_rps() / 2.5,
        "AMP4EC must stay within 2.5x of monolithic throughput"
    );
    assert!(
        cache.throughput_rps() > mono.throughput_rps(),
        "+Cache must beat monolithic throughput"
    );
    assert!(
        cache.mean_latency_ms() < mono.mean_latency_ms(),
        "+Cache must beat monolithic latency"
    );
    assert!(
        cache.mean_latency_ms() < plain.mean_latency_ms(),
        "+Cache must cut latency vs plain AMP4EC"
    );
    assert!(
        rows[0].deploy_bytes == 0,
        "+Cache redeploy must move zero bytes (paper: 100MB -> 0)"
    );
    eprintln!("table1: shape assertions PASSED");
}
