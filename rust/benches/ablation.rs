//! Ablation studies for the design choices DESIGN.md calls out:
//!
//!  * batch size (1 vs 8) on end-to-end throughput;
//!  * result-cache capacity sweep (hit rate vs pool size);
//!  * partitioning strategy: paper Eq. 9 vs CPU-weighted vs
//!    profile-guided, on the heterogeneous cluster;
//!  * energy-aware node selection vs latency-optimal (joules per task).
//!
//! `cargo bench --bench ablation`.

use std::sync::Arc;

use amp4ec::cluster::{NodeSpec, PowerModel, SimParams, VirtualNode};
use amp4ec::config::AmpConfig;
use amp4ec::metrics::markdown_table;
use amp4ec::scheduler::{Scheduler, ScoringWeights, TaskRequirements};
use amp4ec::server::EdgeServer;
use amp4ec::workload::Arrival;

const REQUESTS: usize = 24;

fn serve(cfg: AmpConfig, warm: bool, pool: usize) -> (f64, f64, u64) {
    let server = EdgeServer::start(cfg).unwrap();
    if warm {
        server
            .serve_workload(pool, pool, Arrival::Closed, 77)
            .unwrap();
    }
    let r = server
        .serve_workload(REQUESTS, pool, Arrival::Closed, 77)
        .unwrap();
    (
        r.metrics.mean_latency_ms(),
        r.metrics.throughput_rps(),
        r.metrics.cache_hits,
    )
}

fn main() {
    let artifacts = amp4ec::artifacts_dir();

    // ---- batch size ------------------------------------------------------
    let mut rows = Vec::new();
    for batch in [1usize, 8] {
        let mut cfg = AmpConfig::paper_cluster(&artifacts);
        cfg.batch = batch;
        cfg.profiled_partitioning = true;
        let (lat, tput, _) = serve(cfg, false, REQUESTS);
        rows.push(vec![
            format!("batch {batch}"),
            format!("{lat:.1}"),
            format!("{tput:.2}"),
        ]);
    }
    println!(
        "{}",
        markdown_table(
            "Ablation — dynamic batch size (heterogeneous cluster)",
            &["Config", "Mean latency (ms)", "Throughput (req/s)"],
            &rows,
        )
    );

    // ---- cache capacity ---------------------------------------------------
    let mut rows = Vec::new();
    for (entries, pool) in [(0usize, 8usize), (4, 8), (64, 8), (64, 24)] {
        let mut cfg = AmpConfig::paper_cluster(&artifacts);
        cfg.batch = 8;
        cfg.profiled_partitioning = true;
        cfg.cache_entries = if entries == 0 { None } else { Some(entries) };
        let (lat, tput, hits) = serve(cfg, entries > 0, pool);
        rows.push(vec![
            format!("{entries} entries / pool {pool}"),
            format!("{hits}/{REQUESTS}"),
            format!("{lat:.1}"),
            format!("{tput:.2}"),
        ]);
    }
    println!(
        "{}",
        markdown_table(
            "Ablation — result-cache capacity vs input pool",
            &["Cache / pool", "Hits", "Mean latency (ms)", "Throughput (req/s)"],
            &rows,
        )
    );

    // ---- partitioning strategy -------------------------------------------
    let mut rows = Vec::new();
    for (name, weighted, profiled) in [
        ("paper Eq. 9 equal-cost", false, false),
        ("CPU-weighted Eq. 9", true, false),
        ("profile-guided + CPU-weighted", false, true),
    ] {
        let mut cfg = AmpConfig::paper_cluster(&artifacts);
        cfg.batch = 8;
        cfg.weighted_partitioning = weighted;
        cfg.profiled_partitioning = profiled;
        let (lat, tput, _) = serve(cfg, false, REQUESTS);
        rows.push(vec![
            name.to_string(),
            format!("{lat:.1}"),
            format!("{tput:.2}"),
        ]);
    }
    println!(
        "{}",
        markdown_table(
            "Ablation — partitioning strategy (3-node heterogeneous cluster, batch 8)",
            &["Strategy", "Mean latency (ms)", "Throughput (req/s)"],
            &rows,
        )
    );

    // ---- energy-aware selection (synthetic, no artifacts needed) ----------
    let params = SimParams { runtime_overhead_mb: 0.0, ..SimParams::default() };
    let hungry = Arc::new(VirtualNode::new(
        0,
        NodeSpec::new("hungry", 1.0, 1024.0).with_power(PowerModel {
            idle_watts: 3.0,
            busy_watts: 15.0,
            net_joules_per_byte: 0.0,
        }),
        params.clone(),
    ));
    let frugal = Arc::new(VirtualNode::new(
        1,
        NodeSpec::new("frugal", 1.0, 1024.0).with_power(PowerModel {
            idle_watts: 2.0,
            busy_watts: 4.0,
            net_joules_per_byte: 0.0,
        }),
        params,
    ));
    let nodes = vec![hungry, frugal];
    let req = TaskRequirements::default();
    let tasks = 200;
    let est_ms = 50.0;

    let mut rows = Vec::new();
    for (name, energy_aware) in [("latency-optimal NSA", false),
                                 ("energy-aware (5% tolerance band)", true)] {
        let sched = Scheduler::new(ScoringWeights::default());
        let mut joules = 0.0;
        for t in 0..tasks {
            let pick = if energy_aware {
                sched.select_node_energy_aware(&nodes, &req, est_ms, 1000, 0.05)
            } else {
                sched.select_node(&nodes, &req)
            };
            let (node, _) = pick.expect("selection");
            joules += node.predict_task_joules(est_ms, 1000);
            sched.task_started(node.id());
            if t >= 2 {
                // steady-state completion
                sched.task_completed(node.id(), est_ms);
            }
        }
        rows.push(vec![
            name.to_string(),
            format!("{joules:.1}"),
            format!("{:.3}", joules / tasks as f64),
        ]);
    }
    println!(
        "{}",
        markdown_table(
            "Ablation — energy-aware node selection (200 tasks, 2 nodes, synthetic)",
            &["Policy", "Total marginal J", "J per task"],
            &rows,
        )
    );
    eprintln!("ablation: done");
}
