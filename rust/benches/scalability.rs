//! §IV-E regeneration: scalability analysis.
//!
//! Paper claims: near-linear performance scaling up to three edge nodes,
//! consistent load balancing, and monitoring overhead <= 1% CPU. The bench
//! sweeps 1..=4 identical nodes, measures throughput on a fixed workload,
//! and self-measures the monitor thread. `cargo bench --bench scalability`.
//!
//! Partitions are profile-guided (`plan_measured` over a one-shot
//! calibration of per-block execution time): the Eq. 9 static cost model
//! prices the classifier at ~3% of the model while it measures at ~45%,
//! so Eq. 9 plans bottleneck one stage and cap pipeline scaling. The
//! profile-guided planner is the paper's own §V "automate partition
//! optimization" future-work item.
//!
//! Nodes use the Low profile (0.4 CPU): on this single-core build host the
//! cgroup-quota dilation is what creates overlap headroom for pipelining —
//! at 1.0 CPU a single node already saturates the host and no topology
//! could scale.

use amp4ec::config::AmpConfig;
use amp4ec::manifest::Manifest;
use amp4ec::metrics::markdown_table;
use amp4ec::monitor;
use amp4ec::partitioner;
use amp4ec::server::{calibrate_block_costs, EdgeServer};
use amp4ec::workload::Arrival;

const REQUESTS: usize = 40;
const BATCH: usize = 8;

fn run_nodes(n: usize, m: &Manifest, block_ms: &[f64]) -> (f64, f64, f64) {
    let mut cfg = AmpConfig::profile_cluster(
        &amp4ec::artifacts_dir(),
        amp4ec::cluster::Profile::Low,
        n,
    );
    cfg.batch = BATCH;
    let plan = partitioner::plan_measured(m, block_ms, n).unwrap();
    let server = EdgeServer::start_with_plan(cfg, Some(plan)).unwrap();
    let report = server
        .serve_workload(REQUESTS, REQUESTS, Arrival::Closed, 301)
        .unwrap();
    (
        report.metrics.throughput_rps(),
        report.metrics.mean_latency_ms(),
        report.monitor_overhead_pct,
    )
}

fn main() {
    let m = Manifest::load(&amp4ec::artifacts_dir())
        .expect("run `make artifacts` first");
    eprintln!("scalability: calibrating per-block costs...");
    let block_ms = calibrate_block_costs(&m, BATCH).unwrap();
    eprintln!(
        "scalability: calibrated block costs (ms at b{BATCH}): {:?}",
        block_ms.iter().map(|v| format!("{v:.1}")).collect::<Vec<_>>()
    );
    eprintln!("scalability: sweeping 1..=4 nodes x {REQUESTS} requests...");
    let mut rows = Vec::new();
    let mut tputs = Vec::new();
    for n in 1..=4 {
        let (tput, lat, mon) = run_nodes(n, &m, &block_ms);
        tputs.push(tput);
        rows.push(vec![
            format!("{n}"),
            format!("{tput:.2}"),
            format!("{:.2}x", tput / tputs[0]),
            format!("{lat:.1}"),
            format!("{mon:.3}%"),
        ]);
    }
    println!(
        "{}",
        markdown_table(
            "§IV-E — scalability with identical Low-profile (0.4 CPU) nodes, profile-guided partitions",
            &["Nodes", "Throughput (req/s)", "Speedup vs 1 node",
              "Mean latency (ms)", "Monitor CPU"],
            &rows,
        )
    );

    // ---- monitor overhead at the paper's 1 Hz --------------------------
    let cluster = std::sync::Arc::new(amp4ec::cluster::Cluster::new(
        amp4ec::cluster::SimParams::default(),
    ));
    for i in 0..3 {
        cluster.add_node(amp4ec::cluster::NodeSpec::new(
            &format!("n{i}"),
            1.0,
            1024.0,
        ));
    }
    let handle = monitor::spawn(
        std::sync::Arc::clone(&cluster),
        monitor::MonitorConfig {
            sample_interval: std::time::Duration::from_millis(1000),
            history_len: 64,
            ..monitor::MonitorConfig::default()
        },
    );
    std::thread::sleep(std::time::Duration::from_millis(2500));
    let pct = handle.overhead_cpu_pct();
    println!("monitor overhead at 1 Hz over 3 nodes: {pct:.4}% CPU (paper: <= 1%)");
    assert!(pct <= 1.0, "monitor overhead {pct}% exceeds the paper's 1% claim");

    // Shape assertion: scaling 1 -> 3 nodes improves throughput
    // substantially (paper: linear up to 3 nodes).
    assert!(
        tputs[2] > tputs[0] * 1.4,
        "3-node throughput {:.2} should scale well past 1-node {:.2}",
        tputs[2],
        tputs[0]
    );
    eprintln!("scalability: shape assertions PASSED");
}
