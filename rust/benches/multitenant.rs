//! Multi-tenant serving gate (ISSUE 9): co-deployment packing, WFQ
//! isolation under a bursty flood, and the single-tenant bit-identity
//! pin. `cargo bench --bench multitenant`.
//!
//! Four sections, each with hard asserts:
//!
//! - **Isolation**: one model's ingress with tenant weights 4:1. The
//!   victim tenant sends paced triples while the flooding tenant drives
//!   a `Bursty` (on-off) arrival through the same ingress. Gates: the
//!   victim's p99 stays within 2x its run-alone p99, nothing is shed,
//!   and every request from both tenants completes.
//! - **Weight cap**: a fully backlogged two-tenant queue is drained
//!   through a recording service; the flooder's share of the contested
//!   window must sit near its 1/5 weight share.
//! - **Packing**: two models (separate deployers, one synthetic
//!   manifest each) place onto one shared 3-node cluster. Gates: no
//!   overcommitted placement, every node's paging penalty stays 1.0
//!   with both models resident, and releasing both returns every node
//!   to its baseline working set. The two models then *serve*
//!   concurrently through independent ingresses.
//! - **Bit-identity**: the same engine chain served with no tenant
//!   table and with a trivial one-tenant table produces outputs
//!   bit-identical to the serial schedule — the PR-8 path is unchanged.
//!
//! Emits `BENCH_multitenant.json`.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use amp4ec::cluster::{Cluster, NodeSpec, SimParams};
use amp4ec::deployer::ModelDeployer;
use amp4ec::manifest::Manifest;
use amp4ec::metrics::markdown_table;
use amp4ec::pipeline::engine::{
    run_serial, PersistentEngine, PersistentEngineConfig, SimStages,
};
use amp4ec::router::InferenceService;
use amp4ec::runtime::Tensor;
use amp4ec::scheduler::{Scheduler, ScoringWeights};
use amp4ec::serving::{EngineService, IngressConfig, ServiceHandle};
use amp4ec::util::bench::BenchSuite;
use amp4ec::util::json::Json;
use amp4ec::workload::{feed_with, Arrival, InputPool, RequestSpec};

/// Identity service with a fixed service time; records the tenant tag
/// (the input's fill value) per dispatch, in dispatch order.
struct PacedService {
    service: Duration,
    seen: Arc<Mutex<Vec<usize>>>,
}

impl PacedService {
    fn new(service_ms: u64) -> PacedService {
        PacedService {
            service: Duration::from_millis(service_ms),
            seen: Arc::new(Mutex::new(Vec::new())),
        }
    }
}

impl InferenceService for PacedService {
    fn infer_batch(&self, batch: &Tensor) -> anyhow::Result<(Tensor, f64, f64)> {
        thread::sleep(self.service);
        self.seen.lock().unwrap().push(batch.data()[0] as usize);
        Ok((batch.clone(), 0.0, 0.0))
    }
    fn batch_size(&self) -> usize {
        1
    }
    fn model_id(&self) -> u64 {
        0xB16B
    }
}

/// A `[1, 4]` row whose fill value tags the submitting tenant.
fn tagged(tenant: usize) -> Tensor {
    Tensor::new(vec![1, 4], vec![tenant as f32; 4]).unwrap()
}

fn p99(lat_ms: &[f64]) -> f64 {
    let mut sorted = lat_ms.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((sorted.len() as f64 * 0.99).ceil() as usize)
        .saturating_sub(1)
        .min(sorted.len() - 1);
    sorted[idx]
}

/// The victim tenant's closed-loop client: every `tick` it submits a
/// triple back-to-back, waits all three out, and records each request's
/// latency (from the triple's submission). Returns latencies in ms.
fn run_victim(
    handle: &ServiceHandle,
    ticks: usize,
    tick: Duration,
) -> Vec<f64> {
    let start = Instant::now();
    let mut lat_ms = Vec::with_capacity(ticks * 3);
    for i in 0..ticks {
        let target = tick * i as u32;
        let elapsed = start.elapsed();
        if elapsed < target {
            thread::sleep(target - elapsed);
        }
        let t0 = Instant::now();
        let pending: Vec<_> = (0..3)
            .map(|_| {
                handle.request(tagged(0)).submit().expect("victim submit")
            })
            .collect();
        for p in pending {
            p.wait_output().expect("victim request failed");
            lat_ms.push(t0.elapsed().as_secs_f64() * 1e3);
        }
    }
    lat_ms
}

/// A fresh paced ingress with the bench's 4:1 tenant weight table.
fn isolation_handle(
    service_ms: u64,
) -> (ServiceHandle, Arc<Mutex<Vec<usize>>>) {
    let svc = PacedService::new(service_ms);
    let seen = Arc::clone(&svc.seen);
    let handle = ServiceHandle::new(
        Arc::new(svc),
        IngressConfig {
            workers: 1,
            max_wait: Duration::ZERO,
            capacity: 1024,
            tenant_weights: vec![4.0, 1.0],
            ..IngressConfig::default()
        },
        None,
    );
    (handle, seen)
}

/// Synthetic 3-block manifest: ~15 MB of weights per block, tiny
/// activations. `place()` never touches artifacts, so the file names
/// are never opened.
fn packing_manifest() -> Manifest {
    let text = r#"{
        "model": "packbench", "input_hw": 8, "input_channels": 4,
        "num_classes": 10, "batch_sizes": [1], "total_params": 300,
        "blocks": [
            {"index": 0, "name": "a", "in_shape": [8,8,4],
             "out_shape": [8,8,8], "param_count": 100,
             "weights_file": "b0.bin", "weights_bytes": 15728640,
             "artifacts": {"1": "b0.hlo.txt"},
             "layers": [
                {"name":"a.conv","type":"Conv2d","params":288,
                 "k_h":3,"k_w":3,"c_in":4,"c_out":8,"groups":1,"stride":1}
             ]},
            {"index": 1, "name": "b", "in_shape": [8,8,8],
             "out_shape": [8,8,8], "param_count": 100,
             "weights_file": "b1.bin", "weights_bytes": 15728640,
             "artifacts": {"1": "b1.hlo.txt"},
             "layers": [
                {"name":"b.conv","type":"Conv2d","params":576,
                 "k_h":3,"k_w":3,"c_in":8,"c_out":8,"groups":1,"stride":1}
             ]},
            {"index": 2, "name": "classifier", "in_shape": [8,8,8],
             "out_shape": [1,1,10], "param_count": 100,
             "weights_file": "b2.bin", "weights_bytes": 15728640,
             "artifacts": {"1": "b2.hlo.txt"},
             "layers": [
                {"name":"c.fc","type":"Linear","params":90,
                 "n_in":8,"n_out":10}
             ]}
        ]
    }"#;
    Manifest::parse(text, Path::new("/nonexistent")).expect("bench manifest")
}

fn input_off(rows: usize, cols: usize, off: f32) -> Tensor {
    let data = (0..rows * cols)
        .map(|i| (i as f32) * 0.125 - 4.0 + off)
        .collect();
    Tensor::new(vec![rows, cols], data).unwrap()
}

fn main() {
    let mut suite = BenchSuite::new("multitenant");

    // --- Section 1: victim isolation under a bursty flood. ------------
    let service_ms = 5u64;
    let ticks = 30usize;
    let tick = Duration::from_millis(20);
    let flood_requests = 300usize;

    let (alone_handle, _) = isolation_handle(service_ms);
    let alone_lat = run_victim(&alone_handle, ticks, tick);
    let alone_metrics = alone_handle.finish();
    assert_eq!(alone_metrics.completed, (ticks * 3) as u64);
    let p99_alone = p99(&alone_lat);

    let (flood_handle, _) = isolation_handle(service_ms);
    let (flood_lat, flood_sent) = thread::scope(|s| {
        let flooder = s.spawn(|| {
            feed_with(
                &flood_handle,
                &InputPool::new(&[1, 4], 1, 77),
                flood_requests,
                Arrival::Bursty {
                    base_rps: 50.0,
                    burst_rps: 1200.0,
                    on_ms: 150.0,
                    off_ms: 100.0,
                },
                11,
                |_| RequestSpec::default().with_tenant(1),
            )
        });
        let lat = run_victim(&flood_handle, ticks, tick);
        (lat, flooder.join().expect("flooder thread"))
    });
    let flood_metrics = flood_handle.finish();
    let p99_flood = p99(&flood_lat);
    let p99_ratio = p99_flood / p99_alone.max(1e-9);

    assert_eq!(flood_sent, flood_requests, "flooder must submit everything");
    assert_eq!(
        flood_metrics.tenant_completed(0),
        (ticks * 3) as u64,
        "victim requests lost under flood"
    );
    assert_eq!(
        flood_metrics.tenant_completed(1),
        flood_requests as u64,
        "flooder requests lost"
    );
    assert_eq!(
        flood_metrics.tenant_shed(0) + flood_metrics.tenant_shed(1),
        0,
        "no deadlines in play: nothing sheds"
    );
    assert!(
        p99_ratio <= 2.0,
        "victim p99 degraded {p99_ratio:.2}x under flood \
         ({p99_flood:.1} ms vs {p99_alone:.1} ms alone; > 2x bound)"
    );

    // --- Section 2: flooder capped near its weight share. -------------
    let (cap_handle, cap_seen) = isolation_handle(2);
    let mut pending = Vec::new();
    for _ in 0..40 {
        for t in 0..2usize {
            pending.push(
                cap_handle
                    .request(tagged(t))
                    .tenant(t)
                    .submit()
                    .expect("cap submit"),
            );
        }
    }
    for p in pending {
        p.wait_output().expect("cap request failed");
    }
    let cap_metrics = cap_handle.finish();
    assert_eq!(cap_metrics.completed, 80);
    let order = cap_seen.lock().unwrap().clone();
    // Both tenants stay backlogged through the first 40 dispatches (the
    // victim's 40 drain at ~50); the flooder's share there must track
    // its 1/5 weight share, +-0.1 absorbing startup skew.
    let flooder_share =
        order[..40].iter().filter(|&&t| t == 1).count() as f64 / 40.0;
    assert!(
        (flooder_share - 0.2).abs() <= 0.1,
        "flooder took {flooder_share} of the contested window, want ~0.2"
    );

    // --- Section 3: two models pack onto one shared cluster. ----------
    let cluster = Cluster::new(SimParams::default());
    for i in 0..3 {
        cluster.add_node(NodeSpec::new(&format!("edge{i}"), 1.0, 512.0));
    }
    let scheduler = Scheduler::new(ScoringWeights::default());
    let nodes = cluster.online_nodes();
    let baseline_ws: Vec<f64> =
        nodes.iter().map(|n| n.mem_working_set_mb()).collect();

    let deployer_a = ModelDeployer::new(Arc::new(packing_manifest()));
    let deployer_b = ModelDeployer::new(Arc::new(packing_manifest()));
    let plan_a = amp4ec::partitioner::plan(deployer_a.manifest(), 3)
        .expect("plan model A");
    let plan_b = amp4ec::partitioner::plan(deployer_b.manifest(), 3)
        .expect("plan model B");
    let ones_a = vec![1usize; plan_a.partitions.len()];
    let ones_b = vec![1usize; plan_b.partitions.len()];
    let place_a = deployer_a
        .place(&plan_a, &cluster, &scheduler, 1, &ones_a)
        .expect("place model A");
    let place_b = deployer_b
        .place(&plan_b, &cluster, &scheduler, 1, &ones_b)
        .expect("place model B");

    let overcommitted = place_a
        .iter()
        .chain(place_b.iter())
        .filter(|p| p.overcommitted)
        .count();
    assert_eq!(overcommitted, 0, "co-deployment must not overcommit");
    let worst_penalty = nodes
        .iter()
        .map(|n| n.mem_penalty())
        .fold(1.0_f64, f64::max);
    assert_eq!(
        worst_penalty, 1.0,
        "paging penalty with both models resident"
    );
    let packed_mb: f64 = nodes
        .iter()
        .zip(&baseline_ws)
        .map(|(n, base)| n.mem_working_set_mb() - base)
        .sum();
    assert!(
        packed_mb > 80.0,
        "both models' reservations must be live ({packed_mb:.0} MB)"
    );
    deployer_a.release_placement(&place_a);
    deployer_b.release_placement(&place_b);
    for (n, base) in nodes.iter().zip(&baseline_ws) {
        assert!(
            (n.mem_working_set_mb() - base).abs() < 1e-6,
            "release must restore the baseline working set"
        );
    }

    // Both "models" also *serve* concurrently: two independent paced
    // services drain interleaved closed-loop feeds at the same time.
    let (serve_a, _) = isolation_handle(2);
    let (serve_b, _) = isolation_handle(2);
    let t0 = Instant::now();
    let (sent_a, sent_b) = thread::scope(|s| {
        let feeder_b = s.spawn(|| {
            feed_with(
                &serve_b,
                &InputPool::new(&[1, 4], 2, 5),
                40,
                Arrival::Closed,
                6,
                |_| RequestSpec::default(),
            )
        });
        let sent_a = feed_with(
            &serve_a,
            &InputPool::new(&[1, 4], 2, 4),
            40,
            Arrival::Closed,
            5,
            |_| RequestSpec::default(),
        );
        (sent_a, feeder_b.join().expect("model B feeder"))
    });
    let serve_elapsed_ms = t0.elapsed().as_secs_f64() * 1e3;
    let ma = serve_a.finish();
    let mb = serve_b.finish();
    assert_eq!((sent_a, sent_b), (40, 40));
    assert_eq!(ma.completed, 40, "model A dropped requests");
    assert_eq!(mb.completed, 40, "model B dropped requests");

    // --- Section 4: single-tenant runs are bit-identical to PR-8. -----
    let shares = [1.0f64, 0.6, 0.4];
    let serial = SimStages::heterogeneous(&shares, 1.0);
    let inputs: Vec<Tensor> =
        (0..8).map(|i| input_off(1, 8, i as f32)).collect();
    let goldens: Vec<Tensor> = inputs
        .iter()
        .map(|b| run_serial(&serial, b, 1).expect("serial").output)
        .collect();
    let mut ident_runs = Vec::new();
    for weights in [Vec::new(), vec![1.0]] {
        let engine = PersistentEngine::new(
            Arc::new(SimStages::heterogeneous(&shares, 1.0)),
            PersistentEngineConfig {
                micro_batch_rows: 1,
                initial_depth: 4,
                adaptive: None,
                ..Default::default()
            },
        )
        .expect("identity engine");
        let handle = ServiceHandle::new(
            Arc::new(EngineService::new(Arc::new(engine), 1, 4)),
            IngressConfig {
                workers: 1,
                tenant_weights: weights,
                ..IngressConfig::default()
            },
            None,
        );
        let outs: Vec<Tensor> = inputs
            .iter()
            .map(|b| {
                handle
                    .submit(b.clone())
                    .expect("identity submit")
                    .wait_output()
                    .expect("identity output")
            })
            .collect();
        let m = handle.finish();
        assert_eq!(m.completed, inputs.len() as u64);
        for (out, want) in outs.iter().zip(&goldens) {
            assert_eq!(
                out, want,
                "single-tenant serving diverged from the serial schedule"
            );
        }
        ident_runs.push(outs);
    }
    assert_eq!(
        ident_runs[0], ident_runs[1],
        "empty and trivial tenant tables must serve identical bytes"
    );

    // --- Report + JSON. -----------------------------------------------
    println!(
        "{}",
        markdown_table(
            "Multi-tenant serving (weights 4:1, 5 ms service, bursty flood)",
            &["Gate", "Value", "Bound"],
            &[
                vec![
                    "victim p99 alone".into(),
                    format!("{p99_alone:.1} ms"),
                    "-".into(),
                ],
                vec![
                    "victim p99 under flood".into(),
                    format!("{p99_flood:.1} ms"),
                    "<= 2x alone".into(),
                ],
                vec![
                    "flooder contested share".into(),
                    format!("{flooder_share:.2}"),
                    "0.2 +- 0.1".into(),
                ],
                vec![
                    "co-deploy overcommits".into(),
                    format!("{overcommitted}"),
                    "0".into(),
                ],
                vec![
                    "worst paging penalty".into(),
                    format!("{worst_penalty:.2}"),
                    "1.0".into(),
                ],
                vec![
                    "two-model concurrent serve".into(),
                    format!("{serve_elapsed_ms:.0} ms for 2x40"),
                    "both complete".into(),
                ],
            ],
        )
    );

    suite.record_value("victim p99 alone", p99_alone, "ms");
    suite.record_value("victim p99 flooded", p99_flood, "ms");
    suite.record_value("victim p99 ratio", p99_ratio, "x");
    suite.record_value("flooder contested share", flooder_share, "share");
    suite.record_value("co-deploy packed", packed_mb, "MB");

    let mut doc = BTreeMap::new();
    doc.insert("suite".into(), Json::Str("multitenant".into()));
    doc.insert("service_ms".into(), Json::from(service_ms as usize));
    doc.insert(
        "tenant_weights".into(),
        Json::Arr(vec![Json::Num(4.0), Json::Num(1.0)]),
    );
    doc.insert("victim_requests".into(), Json::from(ticks * 3));
    doc.insert("flood_requests".into(), Json::from(flood_requests));
    doc.insert("p99_alone_ms".into(), Json::Num(p99_alone));
    doc.insert("p99_flood_ms".into(), Json::Num(p99_flood));
    doc.insert("p99_ratio".into(), Json::Num(p99_ratio));
    doc.insert("flooder_contested_share".into(), Json::Num(flooder_share));
    doc.insert("overcommitted_placements".into(), Json::from(overcommitted));
    doc.insert("worst_mem_penalty".into(), Json::Num(worst_penalty));
    doc.insert("packed_mb".into(), Json::Num(packed_mb));
    doc.insert(
        "concurrent_serve_elapsed_ms".into(),
        Json::Num(serve_elapsed_ms),
    );
    doc.insert("bit_identical".into(), Json::Bool(true));
    std::fs::write("BENCH_multitenant.json", Json::Obj(doc).to_string())
        .expect("write BENCH_multitenant.json");
    println!("wrote BENCH_multitenant.json");
}
