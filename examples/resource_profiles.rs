//! Resource-profile sweep (paper Table II): average inference time under
//! the High (1.0 CPU / 1 GB), Medium (0.6 / 512 MB) and Low (0.4 / 512 MB)
//! profiles. The paper reports 234.56 / 389.27 / 583.91 ms — ratios
//! 1 : 1.66 : 2.49, i.e. inverse CPU shares; the same ordering and ratios
//! emerge from the cluster's cgroup-style time dilation here.
//!
//! ```bash
//! make artifacts && cargo run --release --example resource_profiles
//! ```

use amp4ec::cluster::Profile;
use amp4ec::config::AmpConfig;
use amp4ec::server::{single_request, EdgeServer};
use amp4ec::util::stats::Summary;
use amp4ec::workload::InputPool;

const ITERATIONS: usize = 20;

fn main() -> anyhow::Result<()> {
    println!(
        "{:<8} {:>5} {:>8} {:>12} {:>12} {:>12}",
        "profile", "cpu", "memMB", "mean ms", "p50 ms", "p95 ms"
    );
    let mut means = Vec::new();
    for profile in [Profile::High, Profile::Medium, Profile::Low] {
        let spec = profile.spec();
        // A 3-node cluster of identical nodes at this profile.
        let cfg = AmpConfig::profile_cluster(&amp4ec::artifacts_dir(), profile, 3);
        let server = EdgeServer::start(cfg)?;
        let pool = InputPool::new(&server.request_shape(), 4, 21);
        let mut lat = Summary::new();
        // Warm-up, then timed sequential inferences (the paper's "average
        // inference time" is per-request service latency).
        single_request(&server, pool.get(0))?;
        for i in 0..ITERATIONS {
            let (_, ms) = single_request(&server, pool.get(i))?;
            lat.record(ms);
        }
        println!(
            "{:<8} {:>5} {:>8} {:>12.2} {:>12.2} {:>12.2}",
            profile.name(),
            spec.cpu_fraction,
            spec.mem_limit_mb,
            lat.mean(),
            lat.p50(),
            lat.p95()
        );
        means.push((profile.name(), lat.mean()));
    }

    let high = means[0].1;
    println!("\nratios vs High (paper: 1.00 / 1.66 / 2.49):");
    for (name, m) in &means {
        println!("  {name:<8} {:.2}", m / high);
    }
    Ok(())
}
