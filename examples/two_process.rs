//! Two-process distributed deployment: the coordinator (this process)
//! drives a heterogeneous sim chain whose stages are hosted by node
//! agents running in *separate OS processes*, dialed over Unix domain
//! sockets — the smallest real instance of the `amp4ec node` split.
//!
//! The parent re-executes itself with `--agent <socket>` to play the
//! agent role (so the example needs no artifacts and no second binary),
//! deploys the paper's 1.0/0.6/0.4 profile across two agents
//! (round-robin: agent 0 hosts stages 0 and 2), streams a few batches
//! through a depth-4 persistent engine, and checks the outputs are
//! bit-identical to the same chain run in-process. The agents run
//! exit-on-idle, so they terminate on their own once the coordinator
//! disconnects.
//!
//! ```bash
//! cargo run --release --example two_process
//! ```

use std::sync::Arc;
use std::time::{Duration, Instant};

use amp4ec::pipeline::engine::{
    PersistentEngine, PersistentEngineConfig, SimStages,
};
use amp4ec::runtime::Tensor;
use amp4ec::transport::agent::NodeAgent;
use amp4ec::transport::{AgentAddr, Transport, WireStages};

const SHARES: &[f64] = &[1.0, 0.6, 0.4];
const NOMINAL_MS: f64 = 2.0;

fn engine_cfg() -> PersistentEngineConfig {
    PersistentEngineConfig {
        micro_batch_rows: 1,
        initial_depth: 4,
        adaptive: None,
        ..Default::default()
    }
}

fn batch(seed: usize) -> Tensor {
    let data = (0..8 * 32)
        .map(|i| (i as f32) * 0.125 - 4.0 + seed as f32)
        .collect();
    Tensor::new(vec![8, 32], data).unwrap()
}

/// Agent role: serve one UDS socket until the coordinator goes away.
fn run_agent(sock: &str) -> anyhow::Result<()> {
    let handle = NodeAgent::serve_uds(sock)?;
    handle.exit_when_idle(true);
    handle.join();
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let mut args = std::env::args().skip(1);
    if let Some(flag) = args.next() {
        if flag == "--agent" {
            let sock = args.next().expect("--agent needs a socket path");
            return run_agent(&sock);
        }
    }

    // ---- coordinator role ---------------------------------------------
    let me = std::env::current_exe()?;
    let dir = std::env::temp_dir();
    let mut children = Vec::new();
    let mut addrs = Vec::new();
    for i in 0..2 {
        let sock =
            dir.join(format!("amp4ec-two-process-{}-{i}.sock", std::process::id()));
        let _ = std::fs::remove_file(&sock);
        let child = std::process::Command::new(&me)
            .arg("--agent")
            .arg(&sock)
            .spawn()?;
        println!("spawned agent {i} (pid {}) on uds:{}", child.id(), sock.display());
        children.push(child);
        addrs.push(AgentAddr::Uds(sock));
    }

    // Dial both agents and ship the stage deployments. Three stages over
    // two agents: stage 2 round-robins back onto agent 0.
    let wire = Arc::new(WireStages::connect_sim(
        &addrs,
        SHARES,
        NOMINAL_MS,
        Duration::from_secs(10),
    )?);
    for stage in 0..SHARES.len() {
        println!("stage {stage} -> {}", wire.endpoint(stage));
    }

    let remote = PersistentEngine::new(Arc::clone(&wire), engine_cfg())?;
    let local = PersistentEngine::new(
        Arc::new(SimStages::heterogeneous(SHARES, NOMINAL_MS)),
        engine_cfg(),
    )?;

    let t0 = Instant::now();
    for seed in 0..4usize {
        let input = batch(seed);
        let r = remote.run(&input)?;
        let l = local.run(&input)?;
        anyhow::ensure!(
            r.output == l.output,
            "batch {seed}: two-process output diverged from in-process"
        );
        println!(
            "batch {seed}: {} rows, sim {:.1} ms — bit-identical to in-process",
            input.shape[0], r.timing.total_ms
        );
    }
    println!(
        "4 batches over 2 agent processes in {:.0} ms wall",
        t0.elapsed().as_secs_f64() * 1e3
    );

    // Drop the engines (and with them the stage connections): the
    // exit-on-idle agents see the disconnect and shut down by themselves.
    drop(remote);
    drop(wire);
    for (i, mut child) in children.into_iter().enumerate() {
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            match child.try_wait()? {
                Some(status) => {
                    println!("agent {i} exited: {status}");
                    break;
                }
                None if Instant::now() >= deadline => {
                    child.kill()?;
                    anyhow::bail!("agent {i} did not exit on idle");
                }
                None => std::thread::sleep(Duration::from_millis(25)),
            }
        }
    }
    println!("two-process deployment verified: outputs bit-identical, agents exited on idle");
    Ok(())
}
