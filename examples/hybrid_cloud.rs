//! Hybrid edge-cloud scheduling (paper §V future work).
//!
//! A "cloud" node has far more compute (no cgroup quota) but sits behind a
//! high-latency, moderate-bandwidth WAN link. The demo deploys the same
//! model three ways and reports latency/throughput:
//!
//!   1. edge-only  — two constrained edge nodes
//!   2. cloud-only — everything offloaded over the WAN
//!   3. hybrid     — early (activation-heavy) blocks on the edge, late
//!                   (compute-heavy) blocks in the cloud: the classic
//!                   Neurosurgeon-style split the WAN link prices in
//!
//! ```bash
//! make artifacts && cargo run --release --example hybrid_cloud
//! ```

use amp4ec::config::{AmpConfig, NodeConfig};
use amp4ec::server::EdgeServer;
use amp4ec::workload::Arrival;

const REQUESTS: usize = 16;

fn edge_node(i: usize) -> NodeConfig {
    NodeConfig::new(&format!("edge-{i}"), 0.6, 512.0)
}

fn cloud_node() -> NodeConfig {
    let mut n = NodeConfig::new("cloud", 1.0, 16_384.0);
    n.link_latency_ms = 40.0; // WAN round-trip half
    n.link_bandwidth_mbps = 200.0;
    n
}

fn run(label: &str, nodes: Vec<NodeConfig>,
       latency_threshold_ms: f64) -> anyhow::Result<(f64, f64)> {
    let mut cfg = AmpConfig::paper_cluster(&amp4ec::artifacts_dir());
    cfg.nodes = nodes;
    cfg.batch = 8;
    cfg.profiled_partitioning = true;
    // The NSA's high-latency guard (Algorithm 1 line 7) must admit the
    // cloud node for the offload configurations.
    cfg.latency_threshold_ms = latency_threshold_ms;
    let server = EdgeServer::start(cfg)?;
    let report = server.serve_workload(REQUESTS, REQUESTS, Arrival::Closed, 31)?;
    let lat = report.metrics.mean_latency_ms();
    let tput = report.metrics.throughput_rps();
    println!(
        "{label:<12} {:>9.1} ms {:>8.2} req/s   comm {:>6.1} ms/req   plan {:?}",
        lat,
        tput,
        report.metrics.mean_comm_ms(),
        report.partition_layer_sizes,
    );
    Ok((lat, tput))
}

fn main() -> anyhow::Result<()> {
    println!(
        "{:<12} {:>12} {:>14} {:>19} {:>8}",
        "config", "mean latency", "throughput", "comm", "plan"
    );
    let (edge_lat, edge_tput) =
        run("edge-only", vec![edge_node(0), edge_node(1)], 100.0)?;
    let (cloud_lat, _) = run("cloud-only", vec![cloud_node()], 100.0)?;
    let (hybrid_lat, hybrid_tput) = run(
        "hybrid",
        vec![edge_node(0), edge_node(1), cloud_node()],
        100.0,
    )?;

    println!("\nobservations:");
    println!(
        "  cloud-only pays the WAN on every request (mean {cloud_lat:.0} ms \
         vs edge {edge_lat:.0} ms at low load);"
    );
    println!(
        "  hybrid offloads the compute-heavy tail across the WAN once per \
         batch: {hybrid_lat:.0} ms mean, {hybrid_tput:.2} req/s \
         (edge-only: {edge_tput:.2} req/s)."
    );
    Ok(())
}
