//! End-to-end validation driver (DESIGN.md deliverable): serve batched
//! inference requests for a real small model (MobileNetV2, 3.5M params)
//! over a heterogeneous 3-node virtual edge cluster, and report
//! latency/throughput for the three Table I configurations:
//!
//!   1. monolithic baseline (single node, serial, unbatched)
//!   2. AMP4EC              (partitioned, NSA-scheduled, batched pipeline)
//!   3. AMP4EC+Cache        (result cache + warm model cache)
//!
//! ```bash
//! make artifacts && cargo run --release --example edge_cluster_serving
//! ```
//!
//! The run is recorded in EXPERIMENTS.md.

use std::sync::Arc;

use amp4ec::baseline::{baseline_node_spec, MonolithicService};
use amp4ec::cluster::{Cluster, SimParams};
use amp4ec::config::AmpConfig;
use amp4ec::manifest::Manifest;
use amp4ec::metrics::RunMetrics;
use amp4ec::server::EdgeServer;
use amp4ec::serving::{IngressConfig, ServiceHandle};
use amp4ec::workload::{feed, Arrival, InputPool};

const REQUESTS: usize = 32; // the paper's batch of 32 inference requests
const DISTINCT: usize = 8;  // input pool (cache-hit opportunity for +Cache)

fn run_monolithic(manifest: &Manifest) -> anyhow::Result<RunMetrics> {
    let cluster = Cluster::new(SimParams::default());
    let id = cluster.add_node(baseline_node_spec());
    let svc = Arc::new(MonolithicService::new(
        manifest,
        cluster.get(id).unwrap(),
        1,
    )?);
    let pool = InputPool::new(svc.input_shape(), DISTINCT, 11);
    // Same unified ingress the distributed configurations ride.
    let handle = ServiceHandle::new(svc, IngressConfig::default(), None);
    feed(&handle, &pool, REQUESTS, Arrival::Closed, 12);
    Ok(handle.finish())
}

fn run_amp4ec(cached: bool) -> anyhow::Result<(RunMetrics, u64)> {
    let mut cfg = if cached {
        AmpConfig::paper_cluster_cached(&amp4ec::artifacts_dir())
    } else {
        AmpConfig::paper_cluster(&amp4ec::artifacts_dir())
    };
    cfg.batch = 8;
    cfg.profiled_partitioning = true;
    let server = EdgeServer::start(cfg)?;
    if cached {
        // Warm the result cache with half the input pool: the measured
        // run then mixes hits (repeated inputs) with misses (fresh ones),
        // like the paper's partially-warm cache.
        server.serve_workload(DISTINCT, DISTINCT, Arrival::Closed, 11)?;
    }
    let pool_size = if cached { DISTINCT * 2 } else { DISTINCT };
    let report = server.serve_workload(REQUESTS, pool_size, Arrival::Closed, 11)?;
    Ok((report.metrics, report.deploy_transfer_bytes))
}

fn row(name: &str, m: &RunMetrics, deploy_mb: f64) {
    let lat = m.latency_summary();
    println!(
        "{name:<16} {:>8.1} {:>8.1} {:>8.1} {:>9.2} {:>7.1} {:>7.2} {:>8.3} {:>9.2} {:>6}",
        lat.mean(),
        lat.p50(),
        lat.p95(),
        m.throughput_rps(),
        m.mean_comm_ms(),
        m.mean_sched_ms(),
        m.stability_score(),
        deploy_mb,
        m.cache_hits,
    );
}

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load(&amp4ec::artifacts_dir())?;
    println!(
        "serving {} requests ({} distinct inputs) of {} across configurations\n",
        REQUESTS, DISTINCT, manifest.model
    );
    println!(
        "{:<16} {:>8} {:>8} {:>8} {:>9} {:>7} {:>7} {:>8} {:>9} {:>6}",
        "config", "mean ms", "p50 ms", "p95 ms", "req/s", "comm", "sched",
        "stabil", "deployMB", "hits"
    );

    let mono = run_monolithic(&manifest)?;
    row("monolithic", &mono, manifest.monolithic.as_ref().unwrap().weights_bytes as f64 / 1e6);

    let (amp, amp_bytes) = run_amp4ec(false)?;
    row("AMP4EC", &amp, amp_bytes as f64 / 1e6);

    let (ampc, ampc_bytes) = run_amp4ec(true)?;
    row("AMP4EC+Cache", &ampc, ampc_bytes as f64 / 1e6);

    println!("\nimprovement vs monolithic:");
    println!(
        "  latency   : {:+.1}% (AMP4EC+Cache mean)",
        (ampc.mean_latency_ms() / mono.mean_latency_ms() - 1.0) * 100.0
    );
    println!(
        "  throughput: {:+.1}% (AMP4EC+Cache)",
        (ampc.throughput_rps() / mono.throughput_rps() - 1.0) * 100.0
    );
    Ok(())
}
