//! Serial vs streamed pipeline execution on heterogeneous profiles.
//!
//! Demonstrates the `pipeline::engine` streaming engine on the
//! virtual-node substrate (no compiled artifacts needed): a 3-stage
//! chain on the paper's 1.0/0.6/0.4 CPU cluster, plus a wider sweep of
//! cluster profiles, comparing the serial schedule (`pipeline::run`
//! semantics) against the streamed schedule at several pipeline depths.
//! All reported times are simulated milliseconds from the engine's
//! critical-path accounting, so the numbers are machine-independent.
//!
//! Run with: `cargo run --example streaming_pipeline`

use amp4ec::metrics::markdown_table;
use amp4ec::pipeline::engine::{
    run_serial, run_streamed, EngineConfig, SimStages,
};
use amp4ec::runtime::Tensor;

fn input(rows: usize, cols: usize) -> Tensor {
    let data = (0..rows * cols).map(|i| (i as f32) * 0.25 - 8.0).collect();
    Tensor::new(vec![rows, cols], data).unwrap()
}

fn main() -> anyhow::Result<()> {
    let profiles: &[(&str, &[f64])] = &[
        ("paper heterogeneous 1.0/0.6/0.4", &[1.0, 0.6, 0.4]),
        ("balanced 0.6 x3", &[0.6, 0.6, 0.6]),
        ("steep 1.0/0.5/0.25/0.25", &[1.0, 0.5, 0.25, 0.25]),
    ];
    let n_micro = 8;
    let batch = input(n_micro, 32);

    for (name, cpus) in profiles {
        let stages = SimStages::heterogeneous(cpus, 3.0);
        let serial = run_serial(&stages, &batch, 1)?;

        let mut rows = vec![vec![
            "serial".to_string(),
            format!("{:.1}", serial.timing.total_ms),
            format!("{:.1}", serial.timing.compute_ms),
            format!("{:.1}", serial.timing.comm_ms),
            "1.00x".to_string(),
        ]];
        for depth in [2usize, 4, 8] {
            let cfg = EngineConfig { micro_batch_rows: 1, max_in_flight: depth };
            let run = run_streamed(&stages, &batch, &cfg)?;
            anyhow::ensure!(
                run.output == serial.output,
                "streamed output diverged from serial"
            );
            rows.push(vec![
                format!("streamed depth {depth}"),
                format!("{:.1}", run.timing.total_ms),
                format!("{:.1}", run.timing.compute_ms),
                format!("{:.1}", run.timing.comm_ms),
                format!("{:.2}x", serial.timing.total_ms / run.timing.total_ms),
            ]);
        }
        println!(
            "{}",
            markdown_table(
                &format!("{name} — {n_micro} micro-batches (sim ms)"),
                &["Schedule", "Total", "Compute", "Comm", "Speedup"],
                &rows,
            )
        );

        // Per-stage view of the deepest streamed run: where the bubbles
        // live tells you which node to upgrade next.
        let cfg = EngineConfig { micro_batch_rows: 1, max_in_flight: 8 };
        let run = run_streamed(&stages, &batch, &cfg)?;
        let total = run.timing.total_ms;
        let stage_rows: Vec<Vec<String>> = run
            .stage_counters
            .iter()
            .map(|c| {
                vec![
                    format!("{}", c.stage),
                    format!("{:.2}", cpus[c.stage]),
                    format!("{:.1}", c.busy_ms),
                    format!("{:.1}", c.bubble_ms),
                    format!("{:.0}%", 100.0 * c.occupancy(total)),
                ]
            })
            .collect();
        println!(
            "{}",
            markdown_table(
                &format!("{name} — per-stage occupancy at depth 8"),
                &["Stage", "CPU share", "Busy ms", "Bubble ms", "Occupancy"],
                &stage_rows,
            )
        );
    }

    println!(
        "The streamed schedule approaches the pipeline bound \
         (fill + n_micro x slowest stage) while serial pays the full sum \
         of stage times per micro-batch; outputs are bit-identical."
    );
    Ok(())
}
