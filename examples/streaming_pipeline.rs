//! Serial vs streamed pipeline execution on heterogeneous profiles.
//!
//! Demonstrates the `pipeline::engine` streaming engine on the
//! virtual-node substrate (no compiled artifacts needed): a 3-stage
//! chain on the paper's 1.0/0.6/0.4 CPU cluster, plus a wider sweep of
//! cluster profiles, comparing the serial schedule (`pipeline::run`
//! semantics) against the streamed schedule at several pipeline depths.
//! All reported times are simulated milliseconds from the engine's
//! critical-path accounting, so the numbers are machine-independent.
//!
//! Run with: `cargo run --example streaming_pipeline`

use std::sync::Arc;

use amp4ec::metrics::markdown_table;
use amp4ec::pipeline::engine::{
    run_serial, run_streamed, AdaptiveDepthConfig, EngineConfig,
    PersistentEngine, PersistentEngineConfig, SimStages,
};
use amp4ec::runtime::Tensor;

fn input(rows: usize, cols: usize) -> Tensor {
    let data = (0..rows * cols).map(|i| (i as f32) * 0.25 - 8.0).collect();
    Tensor::new(vec![rows, cols], data).unwrap()
}

fn main() -> anyhow::Result<()> {
    let profiles: &[(&str, &[f64])] = &[
        ("paper heterogeneous 1.0/0.6/0.4", &[1.0, 0.6, 0.4]),
        ("balanced 0.6 x3", &[0.6, 0.6, 0.6]),
        ("steep 1.0/0.5/0.25/0.25", &[1.0, 0.5, 0.25, 0.25]),
    ];
    let n_micro = 8;
    let batch = input(n_micro, 32);

    for (name, cpus) in profiles {
        let stages = SimStages::heterogeneous(cpus, 3.0);
        let serial = run_serial(&stages, &batch, 1)?;

        let mut rows = vec![vec![
            "serial".to_string(),
            format!("{:.1}", serial.timing.total_ms),
            format!("{:.1}", serial.timing.compute_ms),
            format!("{:.1}", serial.timing.comm_ms),
            "1.00x".to_string(),
        ]];
        for depth in [2usize, 4, 8] {
            let cfg = EngineConfig { micro_batch_rows: 1, max_in_flight: depth };
            let run = run_streamed(&stages, &batch, &cfg)?;
            anyhow::ensure!(
                run.output == serial.output,
                "streamed output diverged from serial"
            );
            rows.push(vec![
                format!("streamed depth {depth}"),
                format!("{:.1}", run.timing.total_ms),
                format!("{:.1}", run.timing.compute_ms),
                format!("{:.1}", run.timing.comm_ms),
                format!("{:.2}x", serial.timing.total_ms / run.timing.total_ms),
            ]);
        }
        println!(
            "{}",
            markdown_table(
                &format!("{name} — {n_micro} micro-batches (sim ms)"),
                &["Schedule", "Total", "Compute", "Comm", "Speedup"],
                &rows,
            )
        );

        // Per-stage view of the deepest streamed run: where the bubbles
        // live tells you which node to upgrade next.
        let cfg = EngineConfig { micro_batch_rows: 1, max_in_flight: 8 };
        let run = run_streamed(&stages, &batch, &cfg)?;
        let total = run.timing.total_ms;
        let stage_rows: Vec<Vec<String>> = run
            .stage_counters
            .iter()
            .map(|c| {
                vec![
                    format!("{}", c.stage),
                    format!("{:.2}", cpus[c.stage]),
                    format!("{:.1}", c.busy_ms),
                    format!("{:.1}", c.bubble_ms),
                    format!("{:.0}%", 100.0 * c.occupancy(total)),
                ]
            })
            .collect();
        println!(
            "{}",
            markdown_table(
                &format!("{name} — per-stage occupancy at depth 8"),
                &["Stage", "CPU share", "Busy ms", "Bubble ms", "Occupancy"],
                &stage_rows,
            )
        );
    }

    println!(
        "The streamed schedule approaches the pipeline bound \
         (fill + n_micro x slowest stage) while serial pays the full sum \
         of stage times per micro-batch; outputs are bit-identical."
    );

    // ---- persistent cross-batch streaming -------------------------------
    // `run_streamed` drains the pipeline between batches; the persistent
    // engine keeps its stage drivers alive so successive batches stream
    // back-to-back, and (optionally) sizes its in-flight window online
    // from observed bubble time.
    let n_batches = 8;
    let per_batch: Vec<Tensor> = (0..n_batches)
        .map(|i| {
            let mut t = input(4, 32);
            for v in t.data_mut() {
                *v += i as f32;
            }
            t
        })
        .collect();
    let stages = SimStages::heterogeneous(&[1.0, 0.6, 0.4], 3.0);
    let cfg = EngineConfig { micro_batch_rows: 1, max_in_flight: 4 };
    let mut drained_ms = 0.0;
    for b in &per_batch {
        drained_ms += run_streamed(&stages, b, &cfg)?.timing.total_ms;
    }

    // Same fixed depth as the drained baseline: the difference is purely
    // the eliminated inter-batch drain.
    let engine = PersistentEngine::new(
        Arc::new(SimStages::heterogeneous(&[1.0, 0.6, 0.4], 3.0)),
        PersistentEngineConfig {
            micro_batch_rows: 1,
            initial_depth: 4,
            adaptive: None,
            ..Default::default()
        },
    )?;
    let handles: Vec<_> = per_batch
        .iter()
        .map(|b| engine.submit(b))
        .collect::<anyhow::Result<_>>()?;
    for h in handles {
        h.wait()?;
    }
    let persistent_ms = engine.makespan_ms();
    println!(
        "\n{n_batches} batches of 4 micro-batches at depth 4: \
         per-super-batch streaming {drained_ms:.1} sim ms; persistent \
         cross-batch {persistent_ms:.1} sim ms ({:.0}% faster).",
        100.0 * (drained_ms / persistent_ms - 1.0),
    );

    // Adaptive window sizing, shown separately so its warm-up from depth
    // 1 doesn't muddy the fixed-depth comparison above: the controller
    // widens while the bottleneck stage reports credit-starved bubbles.
    let adaptive = PersistentEngine::new(
        Arc::new(SimStages::heterogeneous(&[1.0, 0.6, 0.4], 3.0)),
        PersistentEngineConfig {
            micro_batch_rows: 1,
            initial_depth: 1,
            adaptive: Some(AdaptiveDepthConfig::default()),
            ..Default::default()
        },
    )?;
    let mut handles = Vec::new();
    for _round in 0..3 {
        for b in &per_batch {
            handles.push(adaptive.submit(b)?);
        }
    }
    for h in handles {
        h.wait()?;
    }
    let depth = adaptive.depth_report();
    println!(
        "Adaptive window over {} batches: {} -> {} (+{} widenings, -{} \
         narrowings).",
        3 * n_batches,
        depth.initial_depth,
        depth.final_depth,
        depth.widenings,
        depth.narrowings
    );
    Ok(())
}
