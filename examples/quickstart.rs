//! Quickstart: bring up AMP4EC on the default 3-node heterogeneous edge
//! cluster and serve requests through the unified request-level API —
//! a `ServiceHandle` whose `RequestBuilder` carries per-request
//! priority and deadline, returning a non-blocking `ResponseHandle`.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use std::time::Duration;

use amp4ec::config::AmpConfig;
use amp4ec::server::EdgeServer;
use amp4ec::serving::{Outcome, Priority};
use amp4ec::workload::InputPool;

fn main() -> anyhow::Result<()> {
    let cfg = AmpConfig::paper_cluster(&amp4ec::artifacts_dir());
    println!("starting AMP4EC edge cluster:");
    for n in &cfg.nodes {
        println!("  {:<10} cpu={:<4} mem={} MB", n.name, n.cpu, n.mem_mb);
    }

    let server = EdgeServer::start(cfg)?;
    println!("\nmodel    : {} ({} params)", server.manifest.model,
             server.manifest.total_params);
    println!("plan     : {:?} layers per partition", server.plan().layer_sizes());
    println!("placement: partitions on nodes {:?}",
             server.service().deployment_nodes());

    // The unified serving ingress: every request goes through here.
    let handle = server.serve_handle();
    let pool = InputPool::new(&server.request_shape(), 3, 42);

    // A latency-critical request with a deadline, a default-class
    // request, and a background one — submitted together; the ingress
    // dispatches strictly by priority.
    let urgent = handle
        .request(pool.get(0).clone())
        .priority(Priority::HIGH)
        .deadline(Duration::from_secs(10))
        .tag("urgent")
        .submit()?;
    let normal = handle.submit(pool.get(1).clone())?;
    let background = handle
        .request(pool.get(2).clone())
        .priority(Priority::BEST_EFFORT)
        .submit()?;

    match urgent.wait() {
        Outcome::Done(r) => {
            let top1 = r
                .output
                .data()
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, v)| (i, *v))
                .unwrap();
            println!(
                "\nurgent   : {:.1} ms end-to-end, deadline met: {:?}",
                r.latency_ms, r.deadline_met
            );
            println!("top-1    : class {} (logit {:.3})", top1.0, top1.1);
        }
        Outcome::Shed(reason) => println!("\nurgent   : shed ({reason:?})"),
        Outcome::Failed(e) => return Err(e),
    }
    normal.wait_output()?;
    background.wait_output()?;

    let metrics = handle.finish();
    println!(
        "served   : {} requests ({} shed), mean latency {:.1} ms",
        metrics.completed,
        metrics.total_shed(),
        metrics.mean_latency_ms()
    );

    // Parity against the AOT-recorded golden output (same ingress).
    let diff = server.golden_check()?;
    println!("golden   : max abs diff {diff:.2e} (PASS)");
    Ok(())
}
