//! Quickstart: bring up AMP4EC on the default 3-node heterogeneous edge
//! cluster, run one inference, and print where everything went.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use amp4ec::config::AmpConfig;
use amp4ec::server::{single_request, EdgeServer};
use amp4ec::workload::InputPool;

fn main() -> anyhow::Result<()> {
    let cfg = AmpConfig::paper_cluster(&amp4ec::artifacts_dir());
    println!("starting AMP4EC edge cluster:");
    for n in &cfg.nodes {
        println!("  {:<10} cpu={:<4} mem={} MB", n.name, n.cpu, n.mem_mb);
    }

    let server = EdgeServer::start(cfg)?;
    println!("\nmodel    : {} ({} params)", server.manifest.model,
             server.manifest.total_params);
    println!("plan     : {:?} layers per partition", server.plan().layer_sizes());
    println!("placement: partitions on nodes {:?}",
             server.service().deployment_nodes());

    // One synthetic 96x96x3 image.
    let pool = InputPool::new(&server.request_shape(), 1, 42);
    let (logits, ms) = single_request(&server, pool.get(0))?;

    let top1 = logits
        .data
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, v)| (i, *v))
        .unwrap();
    println!("\ninference: {ms:.1} ms end-to-end across the pipeline");
    println!("top-1    : class {} (logit {:.3})", top1.0, top1.1);

    // Parity against the AOT-recorded golden output.
    let diff = server.golden_check()?;
    println!("golden   : max abs diff {diff:.2e} (PASS)");
    Ok(())
}
