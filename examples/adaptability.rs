//! Adaptability scenario (paper §I + §IV-C): the cluster changes while the
//! system serves — a node is lost, then a new device joins — and AMP4EC
//! re-partitions and redeploys each time without dropping service.
//!
//! ```bash
//! make artifacts && cargo run --release --example adaptability
//! ```

use amp4ec::cluster::NodeSpec;
use amp4ec::config::AmpConfig;
use amp4ec::server::EdgeServer;
use amp4ec::workload::Arrival;

fn serve_and_report(server: &EdgeServer, label: &str, n: usize) -> anyhow::Result<()> {
    let report = server.serve_workload(n, n, Arrival::Closed, 5)?;
    let lat = report.metrics.latency_summary();
    println!(
        "  [{label}] {} ok / {} failed | mean {:.0} ms | {:.2} req/s | partitions {:?}",
        report.metrics.completed,
        report.metrics.failed,
        lat.mean(),
        report.metrics.throughput_rps(),
        report.partition_layer_sizes,
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let mut cfg = AmpConfig::paper_cluster(&amp4ec::artifacts_dir());
    cfg.model_cache = true; // redeployments reuse node-local weights
    let server = EdgeServer::start(cfg)?;

    println!("phase 1: standard configuration (3 nodes)");
    assert_eq!(server.plan().partitions.len(), 3);
    serve_and_report(&server, "3 nodes", 12)?;

    println!("\nphase 2: device offline — dropping the low-resource node");
    let victim = server
        .cluster
        .online_nodes()
        .last()
        .map(|n| n.id())
        .expect("nodes");
    server.cluster.remove_node(victim);
    let sizes = server.rebalance()?;
    println!("  re-partitioned to {sizes:?} (paper 2-part: [116, 25])");
    serve_and_report(&server, "2 nodes", 8)?;

    println!("\nphase 3: new device added — a fresh 1-CPU node joins");
    server
        .cluster
        .add_node(NodeSpec::new("edge-new", 1.0, 1024.0));
    let sizes = server.rebalance()?;
    println!("  re-partitioned to {sizes:?}");
    serve_and_report(&server, "3 nodes again", 12)?;

    println!("\nphase 4: scale-up — a fourth node joins");
    server
        .cluster
        .add_node(NodeSpec::new("edge-extra", 0.8, 1024.0));
    let sizes = server.rebalance()?;
    assert_eq!(sizes.len(), 4);
    println!("  re-partitioned to {sizes:?}");
    serve_and_report(&server, "4 nodes", 16)?;

    println!("\nadaptability scenario complete — no dropped requests.");
    Ok(())
}
