"""L2 model structure + numerics: block graph, manifest layer list, param
flattening, block-chain == monolithic == forward_full."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

HW = 32  # small resolution keeps interpret-mode tests fast


@pytest.fixture(scope="module")
def blocks():
    return M.build_blocks(HW)


@pytest.fixture(scope="module")
def params(blocks):
    return M.init_params(blocks, seed=0)


def test_block_count(blocks):
    assert len(blocks) == 20
    assert blocks[0].name == "stem"
    assert blocks[-2].name == "head"
    assert blocks[-1].name == "classifier"


def test_flat_module_list_matches_torchvision(blocks):
    """The paper partitioned torchvision's 141-entry flat module list."""
    layers = M.all_layers(blocks)
    assert len(layers) == 141
    by_type = {}
    for l in layers:
        by_type[l.type] = by_type.get(l.type, 0) + 1
    assert by_type == {"Conv2d": 52, "BatchNorm2d": 52, "ReLU6": 35,
                       "Dropout": 1, "Linear": 1}


def test_total_params_close_to_torchvision(blocks):
    """MobileNetV2 has ~3.5M params (3504872 in torchvision, incl. BN)."""
    manifest_params = sum(l.params for l in M.all_layers(blocks))
    assert manifest_params == 3504872


def test_block_shapes_chain(blocks):
    for prev, nxt in zip(blocks[:-2], blocks[1:-1]):
        assert prev.out_shape == nxt.in_shape, (prev.name, nxt.name)
    # classifier input = head output
    assert blocks[-1].in_shape == blocks[-2].out_shape


def test_stem_halves_resolution(blocks):
    assert blocks[0].in_shape == (HW, HW, 3)
    assert blocks[0].out_shape == (HW // 2, HW // 2, 32)


def test_param_specs_unique_and_counted(blocks):
    seen = set()
    for b in blocks:
        for name, shape in b.param_spec:
            assert name not in seen
            seen.add(name)
            assert all(d > 0 for d in shape)
        assert b.param_count == sum(math.prod(s) for _, s in b.param_spec)


def test_flatten_unflatten_roundtrip(blocks, params):
    b = blocks[3]
    vec = M.flatten_block_params(params, b)
    assert vec.shape == (b.param_count,)
    back = M.unflatten_block_params(vec, b)
    for name, _ in b.param_spec:
        np.testing.assert_array_equal(back[name], params[name])


def test_forward_shapes(blocks, params):
    x = jax.random.normal(jax.random.PRNGKey(1), (2, HW, HW, 3), jnp.float32)
    y = M.forward_full(params, x, blocks)
    assert y.shape == (2, M.NUM_CLASSES)
    assert bool(jnp.all(jnp.isfinite(y)))


def test_block_chain_equals_forward_full(blocks, params):
    x = jax.random.normal(jax.random.PRNGKey(2), (1, HW, HW, 3), jnp.float32)
    h = x
    for b in blocks:
        fn = M.make_block_callable(b)
        vec = M.flatten_block_params(params, b)
        (h,) = fn(vec, h)
    want = M.forward_full(params, x, blocks)
    np.testing.assert_allclose(h, want, rtol=1e-4, atol=1e-4)


def test_monolithic_equals_forward_full(blocks, params):
    x = jax.random.normal(jax.random.PRNGKey(3), (1, HW, HW, 3), jnp.float32)
    w_full = jnp.concatenate(
        [M.flatten_block_params(params, b) for b in blocks])
    (got,) = M.make_monolithic_callable(blocks)(w_full, x)
    want = M.forward_full(params, x, blocks)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_residual_blocks_marked_by_shape(blocks):
    """Blocks with stride 1 and cin==cout must keep shape (residual adds)."""
    for b in blocks[1:-2]:
        if b.in_shape == b.out_shape:
            # residual-capable; function must accept and preserve shape
            assert b.in_shape[2] == b.out_shape[2]


def test_init_params_deterministic(blocks):
    p1 = M.init_params(blocks, seed=7)
    p2 = M.init_params(blocks, seed=7)
    for k in p1:
        np.testing.assert_array_equal(p1[k], p2[k])
    p3 = M.init_params(blocks, seed=8)
    assert any(
        not np.array_equal(p1[k], p3[k]) for k in p1
    )
