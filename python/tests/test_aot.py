"""AOT export checks: HLO text artifacts, weight sidecars, manifest schema,
golden parity pair.  Uses a tiny input resolution so the test stays fast."""

import json
import math
import pathlib
import struct

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M

jax.config.update("jax_platform_name", "cpu")

HW = 16


@pytest.fixture(scope="module")
def export_dir(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    aot.export(out, input_hw=HW, batch_sizes=[1], seed=3,
               skip_monolithic=False, verbose=False)
    return out


@pytest.fixture(scope="module")
def manifest(export_dir):
    return json.loads((export_dir / "manifest.json").read_text())


def test_manifest_schema(manifest):
    assert manifest["model"] == "mobilenet_v2"
    assert manifest["input_hw"] == HW
    assert manifest["num_classes"] == 1000
    assert len(manifest["blocks"]) == 20
    assert sum(len(b["layers"]) for b in manifest["blocks"]) == 141
    assert manifest["total_params"] > 3_000_000


def test_block_artifacts_exist_and_are_hlo(export_dir, manifest):
    for b in manifest["blocks"]:
        for fname in b["artifacts"].values():
            text = (export_dir / fname).read_text()
            assert text.startswith("HloModule"), fname
            # Signature: weight vector + activation input.
            assert "f32" in text


def test_weights_sidecar_sizes(export_dir, manifest):
    for b in manifest["blocks"]:
        size = (export_dir / b["weights_file"]).stat().st_size
        assert size == b["param_count"] * 4 == b["weights_bytes"]


def test_block_shapes_chain_in_manifest(manifest):
    bs = manifest["blocks"]
    for prev, nxt in zip(bs[:-2], bs[1:-1]):
        assert prev["out_shape"] == nxt["in_shape"]


def test_monolithic_artifact(export_dir, manifest):
    mono = manifest["monolithic"]
    text = (export_dir / mono["artifacts"]["1"]).read_text()
    assert text.startswith("HloModule")
    size = (export_dir / mono["weights_file"]).stat().st_size
    assert size == manifest["total_params"] * 4


def test_golden_pair(export_dir, manifest):
    g = manifest["golden"]
    x_bytes = (export_dir / g["input"]).read_bytes()
    y_bytes = (export_dir / g["output"]).read_bytes()
    assert len(x_bytes) == math.prod(g["in_shape"]) * 4
    assert len(y_bytes) == math.prod(g["out_shape"]) * 4
    y = np.frombuffer(y_bytes, dtype="<f4")
    assert np.all(np.isfinite(y))


def test_golden_matches_recomputed_forward(export_dir, manifest):
    """Re-running the model at the manifest's seed reproduces the golden."""
    g = manifest["golden"]
    blocks = M.build_blocks(HW)
    params = M.init_params(blocks, seed=manifest["seed"])
    x = np.frombuffer((export_dir / g["input"]).read_bytes(),
                      dtype="<f4").reshape(g["in_shape"])
    y = M.forward_full(params, jnp.asarray(x), blocks)
    want = np.frombuffer((export_dir / g["output"]).read_bytes(),
                         dtype="<f4").reshape(g["out_shape"])
    np.testing.assert_allclose(np.asarray(y), want, rtol=1e-5, atol=1e-5)


def test_weights_sha256_recorded(export_dir, manifest):
    import hashlib
    b0 = manifest["blocks"][0]
    digest = hashlib.sha256(
        (export_dir / b0["weights_file"]).read_bytes()).hexdigest()
    assert digest == b0["weights_sha256"]
