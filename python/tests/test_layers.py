"""L2 layer builders vs pure-lax oracles (conv via im2col path etc.)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import layers
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def _rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


@pytest.mark.parametrize("stride", [1, 2])
@pytest.mark.parametrize("activation", ["none", "relu6"])
def test_conv2d_3x3_matches_lax(stride, activation):
    x = _rand(0, (2, 12, 12, 3))
    w = _rand(1, (3, 3, 3, 8))
    b = _rand(2, (8,))
    got = layers.conv2d(x, w, b, stride=stride, activation=activation)
    want = ref.conv2d(x, w, b, stride=stride, activation=activation)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_conv1x1_matches_lax():
    x = _rand(0, (2, 6, 6, 16))
    w = _rand(1, (16, 24))
    b = _rand(2, (24,))
    got = layers.conv1x1(x, w, b, activation="relu6")
    want = ref.conv2d(x, w.reshape(1, 1, 16, 24), b, stride=1,
                      activation="relu6")
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    h=st.integers(4, 16),
    cin=st.integers(1, 8),
    cout=st.integers(1, 8),
    stride=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv2d_hypothesis(h, cin, cout, stride, seed):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(k1, (1, h, h, cin), jnp.float32)
    w = jax.random.normal(k2, (3, 3, cin, cout), jnp.float32)
    b = jax.random.normal(k3, (cout,), jnp.float32)
    got = layers.conv2d(x, w, b, stride=stride)
    want = ref.conv2d(x, w, b, stride=stride)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=2e-4)


def test_im2col_shape_and_content():
    x = jnp.arange(1 * 4 * 4 * 2, dtype=jnp.float32).reshape(1, 4, 4, 2)
    cols = layers.im2col(x, 3, 1)
    assert cols.shape == (1, 4, 4, 18)
    # Center patch of the interior pixel (1,1) equals the raw 3x3 window.
    win = x[0, 0:3, 0:3, :].transpose(0, 1, 2).reshape(-1)
    np.testing.assert_allclose(cols[0, 1, 1], win)


def test_global_avg_pool():
    x = _rand(0, (3, 5, 5, 7))
    np.testing.assert_allclose(layers.global_avg_pool(x),
                               jnp.mean(x, axis=(1, 2)), rtol=1e-6)


def test_linear_matches_ref():
    x = _rand(0, (4, 32))
    w = _rand(1, (32, 10))
    b = _rand(2, (10,))
    np.testing.assert_allclose(layers.linear(x, w, b),
                               ref.matmul_bias_act(x, w, b),
                               rtol=1e-4, atol=1e-4)
