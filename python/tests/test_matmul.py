"""L1 correctness: Pallas matmul+bias+act kernel vs the pure-jnp oracle.

This is the CORE correctness signal for the kernel every 1x1 conv, im2col
conv, and the classifier lower to.  Hypothesis sweeps shapes (including
tile-unaligned ones), activations, and tile sizes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul, ref

jax.config.update("jax_platform_name", "cpu")


def _rand(key, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


@pytest.mark.parametrize("activation", matmul.ACTIVATIONS)
@pytest.mark.parametrize(
    "m,k,n",
    [(8, 8, 8), (16, 24, 32), (1, 1280, 1000), (64, 27, 32), (9216, 32, 16)],
)
def test_matmul_matches_ref(m, k, n, activation):
    x = _rand(0, (m, k))
    w = _rand(1, (k, n))
    b = _rand(2, (n,))
    got = matmul.matmul_bias_act(x, w, b, activation=activation)
    want = ref.matmul_bias_act(x, w, b, activation=activation)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=40, deadline=None)
@given(
    m=st.integers(1, 70),
    k=st.integers(1, 70),
    n=st.integers(1, 70),
    activation=st.sampled_from(matmul.ACTIVATIONS),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_hypothesis_shapes(m, k, n, activation, seed):
    kx, kw, kb = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(kx, (m, k), jnp.float32)
    w = jax.random.normal(kw, (k, n), jnp.float32)
    b = jax.random.normal(kb, (n,), jnp.float32)
    got = matmul.matmul_bias_act(x, w, b, activation=activation)
    want = ref.matmul_bias_act(x, w, b, activation=activation)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    bm=st.sampled_from([8, 16, 32, 128]),
    bn=st.sampled_from([8, 16, 32, 128]),
    bk=st.sampled_from([8, 16, 32, 128]),
)
def test_matmul_tile_size_invariance(bm, bn, bk):
    """The result must not depend on the chosen tiling."""
    x = _rand(3, (50, 37))
    w = _rand(4, (37, 41))
    b = _rand(5, (41,))
    got = matmul.matmul_bias_act(x, w, b, activation="relu6",
                                 bm=bm, bn=bn, bk=bk)
    want = ref.matmul_bias_act(x, w, b, activation="relu6")
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_matmul_jit_compatible():
    f = jax.jit(lambda x, w, b: matmul.matmul_bias_act(x, w, b,
                                                       activation="relu6"))
    x, w, b = _rand(0, (16, 16)), _rand(1, (16, 16)), _rand(2, (16,))
    np.testing.assert_allclose(
        f(x, w, b), ref.matmul_bias_act(x, w, b, activation="relu6"),
        rtol=1e-4, atol=1e-4,
    )


def test_matmul_bias_broadcasting_2d():
    x, w = _rand(0, (8, 8)), _rand(1, (8, 8))
    b = _rand(2, (1, 8))
    got = matmul.matmul_bias_act(x, w, b)
    np.testing.assert_allclose(got, ref.matmul_bias_act(x, w, b),
                               rtol=1e-4, atol=1e-4)


def test_matmul_rejects_bad_shapes():
    x, w, b = _rand(0, (4, 5)), _rand(1, (6, 7)), _rand(2, (7,))
    with pytest.raises(ValueError):
        matmul.matmul_bias_act(x, w, b)
    with pytest.raises(ValueError):
        matmul.matmul_bias_act(_rand(0, (4, 6)), w, _rand(2, (3,)))
    with pytest.raises(ValueError):
        matmul.matmul_bias_act(_rand(0, (4, 6)), w, b, activation="gelu")


def test_vmem_footprint_default_tiles_within_budget():
    """Default tiles must fit the ~16 MiB VMEM budget (DESIGN §Perf)."""
    fp = matmul.vmem_footprint_bytes(matmul.DEFAULT_BM, matmul.DEFAULT_BN,
                                     matmul.DEFAULT_BK)
    assert fp < 16 * 1024 * 1024
    # 128x1024 x-tile + 1024x256 w-tile + bias + 2x 128x256 acc/out.
    assert fp == pytest.approx(1_835_008 + 1024, abs=4096)


def test_mxu_utilization_estimate_bounds():
    assert matmul.mxu_utilization_estimate(128, 128, 128) == 1.0
    u = matmul.mxu_utilization_estimate(1, 1280, 1000)
    assert 0.0 < u <= 1.0
