"""Manifest-level checks, including the paper's §IV-D partition sizes.

The rust partitioner re-derives Eq. 1/2/9 costs from manifest attributes;
this file proves the *python-side* manifest carries enough information to
reproduce the paper's exact reported partition sizes [116, 25] and
[108, 16, 17] with the greedy cumulative-cost algorithm (Eq. 3/10).
"""

from compile import model as M


def eq9_cost(l: M.LayerEntry) -> int:
    """Paper Eq. 9, applied to module attributes exactly as written."""
    if l.type == "Conv2d":
        return l.k_h * l.k_w * l.c_in * l.c_out
    if l.type == "Linear":
        return l.n_in * l.n_out
    return l.params


def greedy_partition_sizes(costs: list[int], num_partitions: int) -> list[int]:
    """Paper §III-B B3: accumulate until >= target, then cut."""
    total = sum(costs)
    target = total / num_partitions
    sizes, acc, count = [], 0, 0
    for c in costs:
        acc += c
        count += 1
        if acc >= target and len(sizes) < num_partitions - 1:
            sizes.append(count)
            acc, count = 0, 0
    sizes.append(count)
    return sizes


def test_paper_partition_sizes_reproduce_exactly():
    layers = M.all_layers(M.build_blocks(96))
    costs = [eq9_cost(l) for l in layers]
    assert greedy_partition_sizes(costs, 2) == [116, 25]
    assert greedy_partition_sizes(costs, 3) == [108, 16, 17]


def test_partition_sizes_cover_all_layers():
    layers = M.all_layers(M.build_blocks(96))
    costs = [eq9_cost(l) for l in layers]
    for n in range(1, 6):
        sizes = greedy_partition_sizes(costs, n)
        assert sum(sizes) == len(layers)
        assert len(sizes) == n
        assert all(s > 0 for s in sizes)


def test_partition_sizes_degenerate_above_five():
    """The paper's greedy scheme runs out of cost mass beyond 5 partitions
    on MobileNetV2 (the tail after the last affordable cut is too light):
    it returns fewer partitions than requested. The rust realization pads/
    rebalances at block granularity instead (partitioner::realize)."""
    layers = M.all_layers(M.build_blocks(96))
    costs = [eq9_cost(l) for l in layers]
    for n in (6, 7, 8):
        sizes = greedy_partition_sizes(costs, n)
        assert sum(sizes) == len(layers)
        assert len(sizes) <= n


def test_costs_resolution_independent():
    """Eq. 9 uses only module attributes, so costs must not depend on the
    input resolution the blocks were built for."""
    a = [eq9_cost(l) for l in M.all_layers(M.build_blocks(96))]
    b = [eq9_cost(l) for l in M.all_layers(M.build_blocks(224))]
    assert a == b


def test_depthwise_convs_use_module_channel_attrs():
    """Paper Eq. 1 reads Conv2d.in_channels/out_channels verbatim, which for
    depthwise convs equals C (groups=C) -- preserve that quirk."""
    layers = M.all_layers(M.build_blocks(96))
    dw = [l for l in layers if l.type == "Conv2d" and l.groups > 1]
    assert len(dw) == 17
    for l in dw:
        assert l.c_in == l.c_out == l.groups
        assert l.params == l.k_h * l.k_w * l.c_out  # grouped param count


def test_conv_dominates_cost():
    layers = M.all_layers(M.build_blocks(96))
    conv_cost = sum(eq9_cost(l) for l in layers if l.type == "Conv2d")
    total = sum(eq9_cost(l) for l in layers)
    assert conv_cost / total > 0.9
