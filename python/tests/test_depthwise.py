"""L1 correctness: Pallas depthwise 3x3 kernel vs the lax.conv oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import depthwise, ref

jax.config.update("jax_platform_name", "cpu")


def _rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


@pytest.mark.parametrize("stride", [1, 2])
@pytest.mark.parametrize("activation", ["none", "relu", "relu6"])
@pytest.mark.parametrize("b,h,w,c", [(1, 8, 8, 4), (2, 12, 12, 32),
                                     (1, 48, 48, 96), (8, 6, 6, 384)])
def test_depthwise_matches_ref(b, h, w, c, stride, activation):
    x = _rand(0, (b, h, w, c))
    wk = _rand(1, (3, 3, c))
    bias = _rand(2, (c,))
    got = depthwise.depthwise_conv3x3(x, wk, bias, stride=stride,
                                      activation=activation)
    want = ref.depthwise_conv3x3(x, wk, bias, stride=stride,
                                 activation=activation)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=30, deadline=None)
@given(
    b=st.integers(1, 3),
    h=st.integers(3, 20),
    w=st.integers(3, 20),
    c=st.integers(1, 40),
    stride=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**31 - 1),
)
def test_depthwise_hypothesis(b, h, w, c, stride, seed):
    kx, kw, kb = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(kx, (b, h, w, c), jnp.float32)
    wk = jax.random.normal(kw, (3, 3, c), jnp.float32)
    bias = jax.random.normal(kb, (c,), jnp.float32)
    got = depthwise.depthwise_conv3x3(x, wk, bias, stride=stride)
    want = ref.depthwise_conv3x3(x, wk, bias, stride=stride)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=8, deadline=None)
@given(bc=st.sampled_from([1, 4, 16, 128]))
def test_depthwise_channel_block_invariance(bc):
    x = _rand(0, (2, 10, 10, 24))
    wk = _rand(1, (3, 3, 24))
    bias = _rand(2, (24,))
    got = depthwise.depthwise_conv3x3(x, wk, bias, stride=2, bc=bc)
    want = ref.depthwise_conv3x3(x, wk, bias, stride=2)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_same_pad():
    # k=3 s=1: out = in, total pad 2.
    assert depthwise.same_pad(96, 3, 1) == (1, 1)
    # k=3 s=2, even in: total pad 1 (TF SAME: lo 0, hi 1).
    assert depthwise.same_pad(96, 3, 2) == (0, 1)
    assert depthwise.same_pad(7, 3, 2) == (1, 1)


def test_output_shapes():
    x = _rand(0, (1, 13, 13, 5))
    wk = _rand(1, (3, 3, 5))
    bias = _rand(2, (5,))
    assert depthwise.depthwise_conv3x3(x, wk, bias, stride=1).shape == (1, 13, 13, 5)
    assert depthwise.depthwise_conv3x3(x, wk, bias, stride=2).shape == (1, 7, 7, 5)


def test_depthwise_rejects_bad_inputs():
    x = _rand(0, (1, 8, 8, 4))
    with pytest.raises(ValueError):
        depthwise.depthwise_conv3x3(x, _rand(1, (3, 3, 5)), _rand(2, (4,)))
    with pytest.raises(ValueError):
        depthwise.depthwise_conv3x3(x, _rand(1, (3, 3, 4)), _rand(2, (4,)),
                                    stride=3)
    with pytest.raises(ValueError):
        depthwise.depthwise_conv3x3(x[0], _rand(1, (3, 3, 4)), _rand(2, (4,)))


def test_vmem_footprint_largest_stage_within_budget():
    """Largest MobileNetV2 stage plane at 96x96 input fits VMEM."""
    # Stage with largest plane*channels product: 48x48, bc=128.
    fp = depthwise.vmem_footprint_bytes(48, 48, 1, bc=128)
    assert fp < 16 * 1024 * 1024
