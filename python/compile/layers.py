"""L2 layer builders: NHWC conv / pool / linear on top of the L1 kernels.

Every FLOP-carrying op routes through the Pallas kernels in
:mod:`compile.kernels`:

  * 1x1 (pointwise) convs  -> ``matmul.matmul_bias_act`` on ``[B*H*W, C]``;
  * full KxK convs         -> im2col (9 shifted strided slices, pure data
                              movement XLA fuses away) + the same matmul
                              kernel;
  * depthwise 3x3 convs    -> ``depthwise.depthwise_conv3x3``;
  * the classifier Linear  -> the matmul kernel again.

Only reductions/reshapes (global average pool, flatten) stay plain jnp.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import depthwise as dw_kernel
from .kernels import matmul as mm_kernel
from .kernels.depthwise import same_pad


def conv1x1(x: jax.Array, w: jax.Array, b: jax.Array, *,
            activation: str = "none") -> jax.Array:
    """Pointwise conv, NHWC.  ``w``: [Cin, Cout]; ``b``: [Cout]."""
    B, H, W, C = x.shape
    out = mm_kernel.matmul_bias_act(
        x.reshape(B * H * W, C), w, b, activation=activation
    )
    return out.reshape(B, H, W, -1)


def im2col(x: jax.Array, kernel: int, stride: int) -> jax.Array:
    """SAME-padded im2col: NHWC -> [B, Ho, Wo, k*k*C], patch order (dy,dx,c)."""
    B, H, W, C = x.shape
    ph = same_pad(H, kernel, stride)
    pw = same_pad(W, kernel, stride)
    xp = jnp.pad(x, ((0, 0), ph, pw, (0, 0)))
    out_h = -(-H // stride)
    out_w = -(-W // stride)
    patches = []
    for dy in range(kernel):
        for dx in range(kernel):
            patches.append(
                jax.lax.slice(
                    xp,
                    (0, dy, dx, 0),
                    (B, dy + (out_h - 1) * stride + 1,
                     dx + (out_w - 1) * stride + 1, C),
                    (1, stride, stride, 1),
                )
            )
    return jnp.concatenate(patches, axis=-1)


def conv2d(x: jax.Array, w: jax.Array, b: jax.Array, *, stride: int = 1,
           activation: str = "none") -> jax.Array:
    """Full conv via im2col + the Pallas matmul kernel.

    ``w``: [kh, kw, Cin, Cout] (HWIO); ``b``: [Cout].  SAME padding.
    """
    kh, kw, cin, cout = w.shape
    assert kh == kw, "square kernels only"
    B, H, W, C = x.shape
    assert C == cin, (x.shape, w.shape)
    cols = im2col(x, kh, stride)  # [B, Ho, Wo, kh*kw*C]
    Bo, Ho, Wo, K = cols.shape
    out = mm_kernel.matmul_bias_act(
        cols.reshape(Bo * Ho * Wo, K),
        w.reshape(kh * kw * cin, cout),
        b,
        activation=activation,
    )
    return out.reshape(Bo, Ho, Wo, cout)


def depthwise3x3(x: jax.Array, w: jax.Array, b: jax.Array, *,
                 stride: int = 1, activation: str = "relu6") -> jax.Array:
    """Depthwise 3x3 conv via the Pallas kernel. ``w``: [3, 3, C]."""
    return dw_kernel.depthwise_conv3x3(
        x, w, b, stride=stride, activation=activation
    )


def global_avg_pool(x: jax.Array) -> jax.Array:
    """NHWC -> [B, C]."""
    return jnp.mean(x, axis=(1, 2))


def linear(x: jax.Array, w: jax.Array, b: jax.Array, *,
           activation: str = "none") -> jax.Array:
    """Dense layer via the Pallas matmul kernel. ``w``: [Nin, Nout]."""
    return mm_kernel.matmul_bias_act(x, w, b, activation=activation)
