"""L1 Pallas kernel: depthwise 3x3 convolution (stride 1 or 2, SAME pad).

MobileNetV2's inverted-residual blocks interleave the pointwise matmuls
(see :mod:`matmul`) with depthwise 3x3 convs.  Depthwise convs are
memory-bound (9 MACs per element loaded), so the kernel is structured for
bandwidth, not the MXU:

  * grid = (batch, channel-blocks); each step owns a full padded spatial
    plane for a slab of channels -- ``(1, Hp, Wp, bc)`` -- which at every
    MobileNetV2 stage on a 96x96 input is <= 98*98*128*4B = 4.7 MiB, well
    inside VMEM;
  * the 3x3 taps unroll into 9 shifted multiply-adds over the VPU (fully
    vectorized over W and C); there is no matmul to feed the MXU, which is
    the correct TPU mapping for depthwise (channels stay in lanes);
  * bias + activation are fused, output written once.

Spatial SAME-padding happens in the wrapper (outside the kernel) so the
BlockSpec sees a static padded shape; channel padding rounds C up to the
channel-block size.  ``interpret=True`` as everywhere (see matmul.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BC = 128


def same_pad(size: int, kernel: int, stride: int) -> tuple[int, int]:
    """TensorFlow-style SAME padding amounts (lo, hi) for one dimension."""
    out = -(-size // stride)  # ceil div
    total = max((out - 1) * stride + kernel - size, 0)
    lo = total // 2
    return lo, total - lo


def _dw_kernel(x_ref, w_ref, b_ref, o_ref, *, stride: int, out_h: int,
               out_w: int, activation: str):
    """One (batch, channel-block) step: 9 shifted MACs over the plane."""
    x = x_ref[0]  # [Hp, Wp, bc]
    acc = jnp.zeros((out_h, out_w, x.shape[-1]), jnp.float32)
    for dy in range(3):
        for dx in range(3):
            window = jax.lax.slice(
                x,
                (dy, dx, 0),
                (dy + (out_h - 1) * stride + 1, dx + (out_w - 1) * stride + 1,
                 x.shape[-1]),
                (stride, stride, 1),
            )
            acc += window * w_ref[dy, dx]
    out = acc + b_ref[0]
    if activation == "relu6":
        out = jnp.minimum(jnp.maximum(out, 0.0), 6.0)
    elif activation == "relu":
        out = jnp.maximum(out, 0.0)
    o_ref[0] = out


def depthwise_conv3x3(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    *,
    stride: int = 1,
    activation: str = "relu6",
    bc: int = DEFAULT_BC,
) -> jax.Array:
    """Depthwise 3x3 conv, NHWC, SAME padding.

    Args:
      x: ``[B, H, W, C]`` f32.
      w: ``[3, 3, C]`` f32 per-channel taps.
      b: ``[C]`` f32 bias.
      stride: 1 or 2.
      activation: "none" | "relu" | "relu6" (fused).
      bc: channel-block size for the grid.
    """
    if x.ndim != 4:
        raise ValueError(f"x must be NHWC rank 4, got {x.shape}")
    if w.shape[:2] != (3, 3) or w.shape[2] != x.shape[3]:
        raise ValueError(f"w must be [3,3,C={x.shape[3]}], got {w.shape}")
    if stride not in (1, 2):
        raise ValueError(f"stride must be 1 or 2, got {stride}")
    B, H, W, C = x.shape
    ph = same_pad(H, 3, stride)
    pw = same_pad(W, 3, stride)
    out_h = -(-H // stride)
    out_w = -(-W // stride)

    bc_ = min(bc, C)
    Cp = (C + bc_ - 1) // bc_ * bc_
    xp = jnp.pad(x, ((0, 0), ph, pw, (0, Cp - C)))
    wp = jnp.pad(w, ((0, 0), (0, 0), (0, Cp - C)))
    bp = jnp.pad(b.reshape(1, -1), ((0, 0), (0, Cp - C)))
    Hp, Wp = xp.shape[1], xp.shape[2]

    grid = (B, Cp // bc_)
    out = pl.pallas_call(
        functools.partial(
            _dw_kernel, stride=stride, out_h=out_h, out_w=out_w,
            activation=activation,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Hp, Wp, bc_), lambda n, c: (n, 0, 0, c)),
            pl.BlockSpec((3, 3, bc_), lambda n, c: (0, 0, c)),
            pl.BlockSpec((1, bc_), lambda n, c: (0, c)),
        ],
        out_specs=pl.BlockSpec((1, out_h, out_w, bc_), lambda n, c: (n, 0, 0, c)),
        out_shape=jax.ShapeDtypeStruct((B, out_h, out_w, Cp), jnp.float32),
        interpret=True,
    )(xp, wp, bp)
    if Cp != C:
        out = out[..., :C]
    return out


def vmem_footprint_bytes(h: int, w: int, stride: int, bc: int = DEFAULT_BC) -> int:
    """Estimated VMEM working set of one grid step, for DESIGN §Perf."""
    ph = sum(same_pad(h, 3, stride))
    pw = sum(same_pad(w, 3, stride))
    in_plane = (h + ph) * (w + pw) * bc * 4
    out_plane = (-(-h // stride)) * (-(-w // stride)) * bc * 4
    taps = 9 * bc * 4 + bc * 4
    return in_plane + out_plane + taps
