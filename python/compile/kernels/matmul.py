"""L1 Pallas kernel: tiled matmul with fused bias + activation epilogue.

This is the compute hot-spot of AMP4EC's MobileNetV2 workload: every 1x1
(pointwise) convolution, every im2col'd full convolution, and the classifier
head lower to this kernel.  MobileNetV2's FLOPs are ~90% pointwise convs, so
this single kernel covers the model's roofline-relevant work.

TPU-idiomatic structure (see DESIGN.md "Hardware adaptation"):
  * the (M, N, K) iteration space is tiled into VMEM-sized blocks via
    BlockSpec -- default 128x128x128 f32 tiles keep the working set
    (x + w + acc + out = 4 * 128*128*4B = 256 KiB) far under the ~16 MiB
    VMEM budget and match the 128x128 MXU systolic array;
  * partial products accumulate in an f32 VMEM scratch across the K grid
    dimension (K innermost -> the scratch is live for one (i, j) tile);
  * the bias add + activation epilogue is fused into the last K step, so
    the output tile is written to HBM exactly once.

`interpret=True` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; interpret mode lowers the kernel to plain HLO, which is what
the rust runtime executes.  Real-TPU perf is estimated in EXPERIMENTS.md.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Activation tags understood by the fused epilogue.
ACTIVATIONS = ("none", "relu6", "relu")

# Default tile-size caps. The M cap is MXU-shaped; the N/K caps are larger
# so small-M layers (the classifier head sees M = batch) don't shatter into
# long grid loops: a [1, 1280] @ [1280, 1000] matmul under 128^3 tiles is an
# 80-step serial grid, under 128x256x1024 it is 2 steps -- 6.5x faster
# end-to-end on the CPU interpret path and the same VMEM budget class on
# TPU (128*1024*4B x-tile + 1024*256*4B w-tile + acc/out ~= 1.9 MiB << 16
# MiB). See EXPERIMENTS.md §Perf iteration 1.
DEFAULT_BM = 128
DEFAULT_BN = 256
DEFAULT_BK = 1024


def _epilogue(acc, bias, activation: str):
    out = acc + bias
    if activation == "relu6":
        out = jnp.minimum(jnp.maximum(out, 0.0), 6.0)
    elif activation == "relu":
        out = jnp.maximum(out, 0.0)
    elif activation != "none":
        raise ValueError(f"unknown activation {activation!r}")
    return out


def _matmul_kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *, n_k: int, activation: str):
    """One (i, j, k) grid step: acc += x_tile @ w_tile, epilogue on last k."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == n_k - 1)
    def _finish():
        o_ref[...] = _epilogue(acc_ref[...], b_ref[...], activation)


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


def matmul_bias_act(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    *,
    activation: str = "none",
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
) -> jax.Array:
    """Compute ``act(x @ w + b)`` with the tiled Pallas kernel.

    Args:
      x: ``[M, K]`` f32.
      w: ``[K, N]`` f32.
      b: ``[N]`` or ``[1, N]`` f32 bias.
      activation: one of :data:`ACTIVATIONS`.
      bm/bn/bk: tile sizes; clamped to the (padded) problem size.

    Shapes that do not divide the tile sizes are zero-padded on the way in
    and sliced on the way out -- zero padding is exact for matmul + bias
    (padded K contributes 0; padded M/N rows/cols are discarded).
    """
    if activation not in ACTIVATIONS:
        raise ValueError(f"unknown activation {activation!r}")
    if x.ndim != 2 or w.ndim != 2:
        raise ValueError(f"x and w must be rank 2, got {x.shape} @ {w.shape}")
    M, K = x.shape
    K2, N = w.shape
    if K != K2:
        raise ValueError(f"contraction mismatch: {x.shape} @ {w.shape}")
    b = b.reshape(1, -1)
    if b.shape[1] != N:
        raise ValueError(f"bias shape {b.shape} does not match N={N}")

    # Balanced tiling (§Perf iteration 2): pick the smallest tile that
    # still covers the dimension in ceil(dim/cap) steps, so padding never
    # exceeds one 8-lane round-up per step. Naive clamping (`min(cap,
    # dim)`) pads e.g. K=1280 up to 2048 under a 1024 cap — a 60% wasted
    # MACs + an 8 MB weight pad-copy per call; balanced tiling picks
    # bk=640 and pads nothing.
    def _tile(dim: int, cap: int) -> int:
        steps = -(-dim // cap)
        return _round_up(-(-dim // steps), 8)

    bn_ = _tile(N, bn)
    bk_ = _tile(K, bk)
    # §Perf iteration 3: grow the M tile into the remaining VMEM budget.
    # Interpret-mode grids pay a whole-buffer copy per step (the lowered
    # while loop dynamic-update-slices the output), so conv matmuls with
    # huge M and tiny K/N (stem at batch 8: M=18432, K=27, N=32) must not
    # shatter into 144 M-steps. Budget ~3M f32 (~12 MiB) across
    # x(bm*bk) + w(bk*bn) + acc/out(2*bm*bn), floor 128, cap 4096.
    budget_floats = 3 * 1024 * 1024
    bm_cap = max(bm, min(4096, (budget_floats - bk_ * bn_) // (bk_ + 2 * bn_)))
    bm_ = _tile(M, max(bm_cap, 8))
    Mp, Kp, Np = _round_up(M, bm_), _round_up(K, bk_), _round_up(N, bn_)
    xp = jnp.pad(x, ((0, Mp - M), (0, Kp - K))) if (Mp, Kp) != (M, K) else x
    wp = jnp.pad(w, ((0, Kp - K), (0, Np - N))) if (Kp, Np) != (K, N) else w
    bp = jnp.pad(b, ((0, 0), (0, Np - N))) if Np != N else b

    grid = (Mp // bm_, Np // bn_, Kp // bk_)
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=grid[2], activation=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk_, bn_), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn_), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.float32)],
        interpret=True,
    )(xp, wp, bp)
    if (Mp, Np) != (M, N):
        out = out[:M, :N]
    return out


def vmem_footprint_bytes(bm: int, bn: int, bk: int) -> int:
    """Estimated VMEM working set of one grid step (f32), for DESIGN §Perf."""
    x_tile = bm * bk * 4
    w_tile = bk * bn * 4
    b_tile = bn * 4
    acc = bm * bn * 4
    out = bm * bn * 4
    return x_tile + w_tile + b_tile + acc + out


def mxu_utilization_estimate(m: int, n: int, k: int, bm: int = DEFAULT_BM,
                             bn: int = DEFAULT_BN, bk: int = DEFAULT_BK) -> float:
    """Fraction of MXU work that is useful (vs padding), for DESIGN §Perf.

    Mirrors the *balanced* tiling `matmul_bias_act` actually performs, so
    the estimate reflects the shipped BlockSpec schedule.
    """

    def _tile(dim: int, cap: int) -> int:
        steps = -(-dim // cap)
        return _round_up(-(-dim // steps), 8)

    bn_ = _tile(n, bn)
    bk_ = _tile(k, bk)
    budget_floats = 3 * 1024 * 1024
    bm_cap = max(bm, min(4096, (budget_floats - bk_ * bn_) // (bk_ + 2 * bn_)))
    bm_ = _tile(m, max(bm_cap, 8))
    mp, np_, kp = _round_up(m, bm_), _round_up(n, bn_), _round_up(k, bk_)
    useful = m * n * k
    issued = mp * np_ * kp
    return useful / issued if issued else 0.0
