"""Pure-jnp correctness oracles for the L1 Pallas kernels.

These are the ground truth the pytest suite compares the kernels against
(``assert_allclose``).  They are deliberately written with stock
``jnp`` / ``lax`` ops and no Pallas machinery.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def apply_activation(x: jax.Array, activation: str) -> jax.Array:
    if activation == "none":
        return x
    if activation == "relu":
        return jnp.maximum(x, 0.0)
    if activation == "relu6":
        return jnp.clip(x, 0.0, 6.0)
    raise ValueError(f"unknown activation {activation!r}")


def matmul_bias_act(x: jax.Array, w: jax.Array, b: jax.Array,
                    *, activation: str = "none") -> jax.Array:
    """Oracle for kernels.matmul.matmul_bias_act."""
    return apply_activation(x @ w + b.reshape(1, -1), activation)


def depthwise_conv3x3(x: jax.Array, w: jax.Array, b: jax.Array,
                      *, stride: int = 1,
                      activation: str = "relu6") -> jax.Array:
    """Oracle for kernels.depthwise.depthwise_conv3x3 (NHWC, SAME)."""
    C = x.shape[3]
    # lax conv wants [H, W, in/groups=1, C] filters for depthwise.
    filt = w.reshape(3, 3, 1, C)
    out = jax.lax.conv_general_dilated(
        x, filt,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=C,
    )
    return apply_activation(out + b.reshape(1, 1, 1, -1), activation)


def conv2d(x: jax.Array, w: jax.Array, b: jax.Array, *, stride: int = 1,
           activation: str = "none") -> jax.Array:
    """Oracle for a full NHWC conv (used for the im2col path), SAME pad.

    w: [kh, kw, Cin, Cout].
    """
    out = jax.lax.conv_general_dilated(
        x, w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return apply_activation(out + b.reshape(1, 1, 1, -1), activation)
