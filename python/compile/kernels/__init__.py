"""L1 Pallas kernels for AMP4EC's MobileNetV2 workload.

``matmul``     -- tiled matmul + bias + activation (pointwise convs, im2col
                  convs, classifier).
``depthwise``  -- depthwise 3x3 conv (inverted-residual spatial stage).
``ref``        -- pure-jnp oracles used by the pytest correctness suite.
"""

from . import depthwise, matmul, ref  # noqa: F401

__all__ = ["depthwise", "matmul", "ref"]
