"""AOT export: lower every model block (and the monolithic model) to HLO
*text* and write the artifacts the rust runtime consumes.

Why text and not ``.serialize()``: jax >= 0.5 emits HloModuleProto with
64-bit instruction ids which the xla crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly.  See /opt/xla-example/README.md.

Outputs (``artifacts/`` by default):
  block_NN_bB.hlo.txt   -- per-block HLO, signature (w_vec f32[P], x) -> (y,)
  block_NN.weights.bin  -- the block's flattened f32 (little-endian) weights
  model_bB.hlo.txt      -- monolithic whole model (the paper's baseline)
  model.weights.bin     -- all weights concatenated in block order
  golden_input_b1.bin / golden_output_b1.bin -- runtime parity check pair
  manifest.json         -- blocks, 141-layer module list, shapes, files

Weights ship as a runtime *argument* (sidecar .bin), not as HLO constants:
it keeps HLO small/fast to parse and makes the model-transfer bytes explicit
-- that payload is exactly what AMP4EC's deployer accounts as network
bandwidth in Table I.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import math
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as model_lib


def to_hlo_text(lowered) -> str:
    """jax lowering -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_block(block: model_lib.BlockDef, batch: int) -> str:
    """Lower one block to HLO text with shapes fixed at ``batch``."""
    fn = model_lib.make_block_callable(block)
    w_spec = jax.ShapeDtypeStruct((block.param_count,), jnp.float32)
    h, w, c = block.in_shape
    if block.name == "classifier":
        x_spec = jax.ShapeDtypeStruct((batch, h, w, c), jnp.float32)
    else:
        x_spec = jax.ShapeDtypeStruct((batch, h, w, c), jnp.float32)
    return to_hlo_text(jax.jit(fn).lower(w_spec, x_spec))


def lower_monolithic(blocks: list[model_lib.BlockDef], batch: int,
                     input_hw: int) -> str:
    fn = model_lib.make_monolithic_callable(blocks)
    total = sum(b.param_count for b in blocks)
    w_spec = jax.ShapeDtypeStruct((total,), jnp.float32)
    x_spec = jax.ShapeDtypeStruct((batch, input_hw, input_hw, 3), jnp.float32)
    return to_hlo_text(jax.jit(fn).lower(w_spec, x_spec))


def write_f32(path: pathlib.Path, arr: jax.Array) -> int:
    data = np.asarray(arr, dtype="<f4").tobytes()
    path.write_bytes(data)
    return len(data)


def sha256(path: pathlib.Path) -> str:
    return hashlib.sha256(path.read_bytes()).hexdigest()


def export(out_dir: pathlib.Path, *, input_hw: int, batch_sizes: list[int],
           seed: int, skip_monolithic: bool = False,
           verbose: bool = True) -> dict:
    out_dir.mkdir(parents=True, exist_ok=True)
    t0 = time.time()
    blocks = model_lib.build_blocks(input_hw)
    params = model_lib.init_params(blocks, seed)

    manifest: dict = {
        "model": "mobilenet_v2",
        "version": 1,
        "input_hw": input_hw,
        "input_channels": 3,
        "num_classes": model_lib.NUM_CLASSES,
        "batch_sizes": batch_sizes,
        "seed": seed,
        "total_params": int(sum(b.param_count for b in blocks)),
        "blocks": [],
    }

    for b in blocks:
        w_vec = model_lib.flatten_block_params(params, b)
        wfile = out_dir / f"block_{b.index:02d}.weights.bin"
        nbytes = write_f32(wfile, w_vec)
        artifacts = {}
        for batch in batch_sizes:
            hlo = lower_block(b, batch)
            afile = out_dir / f"block_{b.index:02d}_b{batch}.hlo.txt"
            afile.write_text(hlo)
            artifacts[str(batch)] = afile.name
            if verbose:
                print(f"  block {b.index:02d} ({b.name}) b{batch}: "
                      f"{len(hlo)//1024} KiB hlo", flush=True)
        manifest["blocks"].append({
            "index": b.index,
            "name": b.name,
            "in_shape": list(b.in_shape),
            "out_shape": list(b.out_shape),
            "param_count": int(b.param_count),
            "weights_file": wfile.name,
            "weights_bytes": nbytes,
            "weights_sha256": sha256(wfile),
            "artifacts": artifacts,
            "layers": [l.to_json() for l in b.layers],
        })

    # Monolithic baseline artifact.
    if not skip_monolithic:
        w_full = jnp.concatenate(
            [model_lib.flatten_block_params(params, b) for b in blocks]
        )
        wfile = out_dir / "model.weights.bin"
        write_f32(wfile, w_full)
        mono_artifacts = {}
        for batch in batch_sizes:
            hlo = lower_monolithic(blocks, batch, input_hw)
            afile = out_dir / f"model_b{batch}.hlo.txt"
            afile.write_text(hlo)
            mono_artifacts[str(batch)] = afile.name
            if verbose:
                print(f"  monolithic b{batch}: {len(hlo)//1024} KiB hlo",
                      flush=True)
        manifest["monolithic"] = {
            "weights_file": wfile.name,
            "weights_bytes": int(w_full.size * 4),
            "artifacts": mono_artifacts,
        }

    # Golden parity pair (batch 1): rust executes the chain / the monolith
    # and must match this output to tolerance.
    key = jax.random.PRNGKey(seed + 1)
    x = jax.random.normal(key, (1, input_hw, input_hw, 3), jnp.float32)
    y = model_lib.forward_full(params, x, blocks)
    write_f32(out_dir / "golden_input_b1.bin", x)
    write_f32(out_dir / "golden_output_b1.bin", y)
    manifest["golden"] = {
        "input": "golden_input_b1.bin",
        "output": "golden_output_b1.bin",
        "batch": 1,
        "in_shape": [1, input_hw, input_hw, 3],
        "out_shape": [1, model_lib.NUM_CLASSES],
        "tolerance": 1e-3,
    }

    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=1))
    if verbose:
        print(f"export done in {time.time() - t0:.1f}s -> {out_dir}")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--input-hw", type=int, default=model_lib.INPUT_HW)
    ap.add_argument("--batch-sizes", type=int, nargs="+",
                    default=list(model_lib.BATCH_SIZES))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--skip-monolithic", action="store_true")
    args = ap.parse_args()
    export(pathlib.Path(args.out_dir), input_hw=args.input_hw,
           batch_sizes=args.batch_sizes, seed=args.seed,
           skip_monolithic=args.skip_monolithic)


if __name__ == "__main__":
    main()
