"""L2 model: MobileNetV2 (inference, BN folded) built from the L1 kernels.

The model mirrors torchvision's ``mobilenet_v2`` exactly at the *module
list* level: the manifest this file generates has the same 141 flat module
entries (52 Conv2d + 52 BatchNorm2d + 35 ReLU6 + Dropout + Linear) the paper
partitioned -- its reported partition sizes [116, 25] and [108, 16, 17] sum
to 141.  The rust partitioner consumes these entries and re-derives the
paper's Eq. 1/2/9 costs from the recorded module attributes.

For *compute* we fold BN into the preceding conv (inference-time identity
transformation), so each block function is conv+bias chains routed through
the Pallas kernels.  Weights are deterministic (seeded); the paper's
evaluation is latency/throughput only, never accuracy, so weight values are
irrelevant (see DESIGN.md "Substitutions").

Artifact granularity is the *block*: stem, 17 inverted residuals, head
conv, pool+classifier -- 20 blocks.  Each block is lowered separately by
``aot.py``; a partition at runtime is a contiguous range of blocks, so the
rust side can realize any boundary the partitioning algorithm chooses.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable

import jax
import jax.numpy as jnp

from . import layers

# ---------------------------------------------------------------------------
# Architecture description (torchvision mobilenet_v2, width_mult=1.0)
# ---------------------------------------------------------------------------

# (expansion t, output channels c, repeats n, first stride s)
IR_SETTINGS = [
    (1, 16, 1, 1),
    (6, 24, 2, 2),
    (6, 32, 3, 2),
    (6, 64, 4, 2),
    (6, 96, 3, 1),
    (6, 160, 3, 2),
    (6, 320, 1, 1),
]
STEM_CHANNELS = 32
HEAD_CHANNELS = 1280
NUM_CLASSES = 1000

# Default AOT input resolution (paper used 224; we use 96 -- see DESIGN.md).
INPUT_HW = 96
BATCH_SIZES = (1, 8)


@dataclasses.dataclass(frozen=True)
class LayerEntry:
    """One flat module entry, as the paper's partitioner saw them."""

    name: str          # torchvision-style dotted path, e.g. "features.2.conv.1.0"
    type: str          # Conv2d | BatchNorm2d | ReLU6 | Dropout | Linear
    params: int        # trainable parameter count of the module
    # Conv2d attrs (paper Eq. 1/9); 0 when not applicable.
    k_h: int = 0
    k_w: int = 0
    c_in: int = 0
    c_out: int = 0
    groups: int = 1
    stride: int = 1
    # Linear attrs (paper Eq. 2/9); 0 when not applicable.
    n_in: int = 0
    n_out: int = 0

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class BlockDef:
    """One AOT unit: a contiguous run of layers with a single jax function."""

    index: int
    name: str
    layers: list[LayerEntry]
    # (key, shape) in flattening order; key indexes the params dict.
    param_spec: list[tuple[str, tuple[int, ...]]]
    in_shape: tuple[int, int, int]   # (H, W, C); classifier uses (1, 1, C)
    out_shape: tuple[int, int, int]
    fn: Callable  # fn(params: dict, x) -> y

    @property
    def param_count(self) -> int:
        return sum(math.prod(s) for _, s in self.param_spec)

    def flat_len(self) -> int:
        return self.param_count


def _conv_entry(name: str, k: int, cin: int, cout: int, *, groups: int = 1,
                stride: int = 1) -> LayerEntry:
    return LayerEntry(
        name=name, type="Conv2d",
        params=k * k * (cin // groups) * cout,
        k_h=k, k_w=k, c_in=cin, c_out=cout, groups=groups, stride=stride,
    )


def _bn_entry(name: str, c: int) -> LayerEntry:
    return LayerEntry(name=name, type="BatchNorm2d", params=2 * c)


def _relu6_entry(name: str) -> LayerEntry:
    return LayerEntry(name=name, type="ReLU6", params=0)


# ---------------------------------------------------------------------------
# Block builders
# ---------------------------------------------------------------------------


def _stem_block(hw: int) -> BlockDef:
    c = STEM_CHANNELS

    def fn(p: dict, x: jax.Array) -> jax.Array:
        return layers.conv2d(x, p["stem.w"], p["stem.b"], stride=2,
                             activation="relu6")

    return BlockDef(
        index=0,
        name="stem",
        layers=[
            _conv_entry("features.0.0", 3, 3, c, stride=2),
            _bn_entry("features.0.1", c),
            _relu6_entry("features.0.2"),
        ],
        param_spec=[("stem.w", (3, 3, 3, c)), ("stem.b", (c,))],
        in_shape=(hw, hw, 3),
        out_shape=(hw // 2, hw // 2, c),
        fn=fn,
    )


def _ir_block(index: int, feat_idx: int, cin: int, cout: int, t: int,
              stride: int, hw_in: int) -> BlockDef:
    """Inverted residual: [expand 1x1] -> dw 3x3 -> project 1x1 (+res)."""
    hidden = cin * t
    hw_out = -(-hw_in // stride)
    use_res = stride == 1 and cin == cout
    prefix = f"features.{feat_idx}.conv"
    tag = f"b{index:02d}"

    entries: list[LayerEntry] = []
    spec: list[tuple[str, tuple[int, ...]]] = []
    if t != 1:
        entries += [
            _conv_entry(f"{prefix}.0.0", 1, cin, hidden),
            _bn_entry(f"{prefix}.0.1", hidden),
            _relu6_entry(f"{prefix}.0.2"),
        ]
        spec += [(f"{tag}.expand.w", (cin, hidden)),
                 (f"{tag}.expand.b", (hidden,))]
        dw_prefix = f"{prefix}.1"
        proj_name, proj_bn = f"{prefix}.2", f"{prefix}.3"
    else:
        dw_prefix = f"{prefix}.0"
        proj_name, proj_bn = f"{prefix}.1", f"{prefix}.2"
    entries += [
        _conv_entry(f"{dw_prefix}.0", 3, hidden, hidden, groups=hidden,
                    stride=stride),
        _bn_entry(f"{dw_prefix}.1", hidden),
        _relu6_entry(f"{dw_prefix}.2"),
        _conv_entry(proj_name, 1, hidden, cout),
        _bn_entry(proj_bn, cout),
    ]
    spec += [
        (f"{tag}.dw.w", (3, 3, hidden)),
        (f"{tag}.dw.b", (hidden,)),
        (f"{tag}.project.w", (hidden, cout)),
        (f"{tag}.project.b", (cout,)),
    ]

    def fn(p: dict, x: jax.Array) -> jax.Array:
        h = x
        if t != 1:
            h = layers.conv1x1(h, p[f"{tag}.expand.w"], p[f"{tag}.expand.b"],
                               activation="relu6")
        h = layers.depthwise3x3(h, p[f"{tag}.dw.w"], p[f"{tag}.dw.b"],
                                stride=stride, activation="relu6")
        h = layers.conv1x1(h, p[f"{tag}.project.w"], p[f"{tag}.project.b"],
                           activation="none")
        if use_res:
            h = h + x
        return h

    return BlockDef(
        index=index,
        name=f"ir{index}_t{t}_c{cout}_s{stride}",
        layers=entries,
        param_spec=spec,
        in_shape=(hw_in, hw_in, cin),
        out_shape=(hw_out, hw_out, cout),
        fn=fn,
    )


def _head_block(index: int, feat_idx: int, cin: int, hw: int) -> BlockDef:
    c = HEAD_CHANNELS

    def fn(p: dict, x: jax.Array) -> jax.Array:
        return layers.conv1x1(x, p["head.w"], p["head.b"], activation="relu6")

    return BlockDef(
        index=index,
        name="head",
        layers=[
            _conv_entry(f"features.{feat_idx}.0", 1, cin, c),
            _bn_entry(f"features.{feat_idx}.1", c),
            _relu6_entry(f"features.{feat_idx}.2"),
        ],
        param_spec=[("head.w", (cin, c)), ("head.b", (c,))],
        in_shape=(hw, hw, cin),
        out_shape=(hw, hw, c),
        fn=fn,
    )


def _classifier_block(index: int, hw: int) -> BlockDef:
    def fn(p: dict, x: jax.Array) -> jax.Array:
        pooled = layers.global_avg_pool(x)  # [B, HEAD_CHANNELS]
        # Dropout is identity at inference.
        return layers.linear(pooled, p["classifier.w"], p["classifier.b"])

    return BlockDef(
        index=index,
        name="classifier",
        layers=[
            LayerEntry(name="classifier.0", type="Dropout", params=0),
            LayerEntry(
                name="classifier.1", type="Linear",
                params=HEAD_CHANNELS * NUM_CLASSES + NUM_CLASSES,
                n_in=HEAD_CHANNELS, n_out=NUM_CLASSES,
            ),
        ],
        param_spec=[
            ("classifier.w", (HEAD_CHANNELS, NUM_CLASSES)),
            ("classifier.b", (NUM_CLASSES,)),
        ],
        in_shape=(hw, hw, HEAD_CHANNELS),
        out_shape=(1, 1, NUM_CLASSES),
        fn=fn,
    )


def build_blocks(input_hw: int = INPUT_HW) -> list[BlockDef]:
    """The 20 AOT blocks of MobileNetV2 at the given input resolution."""
    blocks = [_stem_block(input_hw)]
    hw = input_hw // 2
    cin = STEM_CHANNELS
    index = 1
    feat_idx = 1
    for t, c, n, s in IR_SETTINGS:
        for rep in range(n):
            stride = s if rep == 0 else 1
            blocks.append(_ir_block(index, feat_idx, cin, c, t, stride, hw))
            hw = -(-hw // stride)
            cin = c
            index += 1
            feat_idx += 1
    blocks.append(_head_block(index, feat_idx, cin, hw))
    blocks.append(_classifier_block(index + 1, hw))
    return blocks


def all_layers(blocks: list[BlockDef]) -> list[LayerEntry]:
    """The flat 141-entry module list, in execution order."""
    out: list[LayerEntry] = []
    for b in blocks:
        out.extend(b.layers)
    return out


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def init_params(blocks: list[BlockDef], seed: int = 0) -> dict[str, jax.Array]:
    """Deterministic He-normal init; biases get small noise so ReLU6 paths
    are numerically non-trivial."""
    params: dict[str, jax.Array] = {}
    key = jax.random.PRNGKey(seed)
    for b in blocks:
        for name, shape in b.param_spec:
            key, k1 = jax.random.split(key)
            if len(shape) == 1:  # bias
                params[name] = 0.01 * jax.random.normal(k1, shape, jnp.float32)
            else:
                fan_in = math.prod(shape[:-1])
                std = math.sqrt(2.0 / fan_in)
                params[name] = std * jax.random.normal(k1, shape, jnp.float32)
    return params


def flatten_block_params(params: dict[str, jax.Array],
                         block: BlockDef) -> jax.Array:
    """Concatenate a block's params into the single f32 vector the HLO takes."""
    return jnp.concatenate(
        [params[name].reshape(-1) for name, _ in block.param_spec]
    )


def unflatten_block_params(vec: jax.Array,
                           block: BlockDef) -> dict[str, jax.Array]:
    """Inverse of :func:`flatten_block_params` (static slices, trace-safe)."""
    out: dict[str, jax.Array] = {}
    off = 0
    for name, shape in block.param_spec:
        n = math.prod(shape)
        out[name] = jax.lax.slice(vec, (off,), (off + n,)).reshape(shape)
        off += n
    return out


def make_block_callable(block: BlockDef) -> Callable:
    """``fn(w_vec, x)`` -- the exact signature the rust runtime executes."""

    def fn(w_vec: jax.Array, x: jax.Array) -> tuple[jax.Array]:
        p = unflatten_block_params(w_vec, block)
        y = block.fn(p, x)
        if block.name == "classifier":
            return (y,)
        return (y,)

    return fn


def forward_full(params: dict[str, jax.Array], x: jax.Array,
                 blocks: list[BlockDef] | None = None) -> jax.Array:
    """Whole-model forward (used for the monolithic artifact + goldens)."""
    blocks = blocks or build_blocks(x.shape[1])
    h = x
    for b in blocks:
        h = b.fn(params, h)
    return h


def make_monolithic_callable(blocks: list[BlockDef]) -> Callable:
    """``fn(w_vec_full, x)`` over the concatenation of all block vectors."""

    def fn(w_vec: jax.Array, x: jax.Array) -> tuple[jax.Array]:
        off = 0
        h = x
        for b in blocks:
            n = b.param_count
            sub = jax.lax.slice(w_vec, (off,), (off + n,))
            p = unflatten_block_params(sub, b)
            h = b.fn(p, h)
            off += n
        return (h,)

    return fn
